"""Model / elastic / training configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``. The config is a
*complete* description of the computation: the model zoo (``repro.models``)
builds init/apply/prefill/decode functions from it, the sharding rules
(``repro.parallel.sharding``) derive partition specs from it, and NeuroForge
(``repro.core.neuroforge``) derives analytical FLOP/byte/collective models
from it.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Elastic (NeuroMorph) configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ElasticConfig:
    """NeuroMorph morphing space attached to a model.

    ``width_fractions`` are the selectable width morph levels (paper's
    "width-wise morphing": fraction of active filters -> fraction of active
    attention heads / kv heads / d_ff columns / SSD heads / MoE top_k).
    ``exit_layers`` are depth-morph exit points, expressed in *layer-group*
    indices (after group ``g`` the hidden state may branch to an exit head).
    The full model is always the last entry implicitly.
    """

    width_fractions: Tuple[float, ...] = (0.5, 1.0)
    exit_layers: Tuple[int, ...] = ()  # e.g. (8, 16) for a 32-layer net
    # Dedicated exit-head behaviour: each exit gets its own final norm; the
    # unembedding is shared (vocab-sized heads per exit would dwarf the
    # backbone — documented adaptation of the paper's per-exit FC heads).
    dedicated_exit_norm: bool = True
    # DistillCycle hyperparameters (paper Eq. 17-18, 20)
    distill_temperature: float = 2.0
    distill_lambda: float = 0.5
    lr_decay_gamma: float = 0.8

    def modes(self, n_groups: int) -> Tuple["MorphMode", ...]:
        """Enumerate all morph modes (cartesian depth x width)."""
        exits = tuple(e for e in self.exit_layers if 0 < e < n_groups)
        depths = exits + (n_groups,)
        out = []
        for d in depths:
            for w in self.width_fractions:
                out.append(MorphMode(depth=d, width=w))
        return tuple(out)


@dataclass(frozen=True)
class MorphMode:
    """One NeuroMorph execution path: run ``depth`` layer groups at ``width``."""

    depth: int  # number of layer groups to run
    width: float  # fraction of active width in (0, 1]

    @property
    def name(self) -> str:
        return f"d{self.depth}w{int(self.width * 100)}"


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0  # 0 -> d_model // n_heads
    activation: str = "swiglu"  # swiglu | squared_relu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    use_rope: bool = True
    rope_theta: float = 10000.0
    tie_embeddings: bool = False

    # Attention variants
    sliding_window: int = 0  # 0 -> full attention; >0 -> SWA window

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim (0 -> d_ff)
    moe_period: int = 1  # MoE every `period` layers (jamba: 2); 1 = every layer
    capacity_factor: float = 1.25
    moe_group_size: int = 512  # dispatch group size (tokens)
    moe_impl: str = "capacity"  # capacity (einsum dispatch) | dense (dropless oracle)

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_d_inner_override: int = 0  # set by NeuroMorph width morphing
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    ssm_chunk: int = 256

    # Hybrid layer pattern: index within period -> "attn" | "ssm".
    # Model layers = pattern repeated n_layers/len(pattern) times.
    layer_pattern: Tuple[str, ...] = ()

    # Encoder-decoder (whisper)
    enc_layers: int = 0
    enc_seq: int = 0  # fixed encoder length (1500 for whisper)

    # Modality frontend stub
    frontend: str = ""  # "" | "audio_stub" | "vision_stub"
    frontend_seq: int = 0  # e.g. 1500 audio frames / 256 image patches
    frontend_dim: int = 0  # embedding dim provided by the stub

    # Elastic / NeuroMorph
    elastic: ElasticConfig = field(default_factory=ElasticConfig)

    # Numerics
    dtype: str = "bfloat16"  # activation dtype
    param_dtype: str = "float32"  # master param dtype (CPU tests); bf16 for dry-run

    # Attention implementation knobs (NeuroForge genome can override)
    attn_impl: str = "auto"  # auto | einsum | chunked  (chunked = O(S*chunk) memory)
    attn_chunk: int = 1024  # kv-block size for chunked attention
    kv_quant: bool = False  # int8 KV cache with per-(pos,head) scales (beyond-paper opt)

    # -- derived -----------------------------------------------------------
    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // max(self.n_heads, 1))
        if self.n_experts and not self.moe_d_ff:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        if not self.layer_pattern:
            kind = "ssm" if self.family == "ssm" else "attn"
            object.__setattr__(self, "layer_pattern", (kind,))
        if self.n_layers % len(self.layer_pattern) != 0:
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"pattern period {len(self.layer_pattern)}"
            )

    # Layer-group (scan) structure -----------------------------------------
    @property
    def period(self) -> int:
        """Layers per scanned group. Dense archs: max(1, pattern)."""
        return len(self.layer_pattern)

    @property
    def n_groups(self) -> int:
        return self.n_layers // self.period

    def layer_kind(self, layer_idx: int) -> str:
        return self.layer_pattern[layer_idx % self.period]

    def layer_is_moe(self, layer_idx: int) -> bool:
        if not self.n_experts:
            return False
        return layer_idx % self.moe_period == (self.moe_period - 1)

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_d_inner_override or self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def attention_free(self) -> bool:
        return all(k == "ssm" for k in self.layer_pattern) and not self.is_encdec

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: SSM/hybrid state or sliding-window attn."""
        has_full_attn = any(k == "attn" for k in self.layer_pattern) and self.sliding_window == 0
        if self.is_encdec:
            has_full_attn = True
        return not has_full_attn or self.family in ("ssm", "hybrid")

    # Vocab padding for sharding (Megatron practice) -------------------------
    def padded_vocab(self, multiple: int = 2048) -> int:
        return int(math.ceil(self.vocab_size / multiple) * multiple)

    # Parameter counting (analytical; mirrors models/ param shapes) ----------
    def param_counts(self) -> dict:
        """Returns dict with total and active (per-token) parameter counts."""
        d, hd = self.d_model, self.head_dim
        counts = {"embed": self.padded_vocab() * d}
        unembed = 0 if self.tie_embeddings else self.padded_vocab() * d
        counts["unembed"] = unembed
        attn = ssm = mlp_dense = moe_total = moe_active = router = 0
        n_mlp_matrices = 3 if self.activation == "swiglu" else 2
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            if kind == "attn":
                attn += d * self.q_dim + self.q_dim * d + 2 * d * self.kv_dim
            else:
                d_in = self.ssm_d_inner
                proj_out = 2 * d_in + 2 * self.ssm_ngroups * self.ssm_state + self.ssm_nheads
                ssm += d * proj_out + d_in * d
                ssm += (d_in + 2 * self.ssm_ngroups * self.ssm_state) * self.ssm_conv
                ssm += 3 * self.ssm_nheads  # A_log, D, dt_bias
            if self.layer_is_moe(i):
                per_expert = n_mlp_matrices * d * self.moe_d_ff
                moe_total += self.n_experts * per_expert
                moe_active += self.top_k * per_expert
                router += d * self.n_experts
            else:
                mlp_dense += n_mlp_matrices * d * self.d_ff
        enc = 0
        if self.is_encdec:
            # encoder self-attn + mlp, decoder cross-attn (added to attn above? no:
            # decoder layers counted in n_layers as self-attn; add cross-attn here)
            enc_attn = self.enc_layers * (2 * d * self.q_dim + 2 * d * self.kv_dim)
            enc_mlp = self.enc_layers * n_mlp_matrices * d * self.d_ff
            cross = self.n_layers * (d * self.q_dim + self.q_dim * d + 2 * d * self.kv_dim)
            enc = enc_attn + enc_mlp + cross
        frontend_proj = self.frontend_dim * d if self.frontend else 0
        counts.update(
            attn=attn, ssm=ssm, mlp=mlp_dense, moe_total=moe_total, router=router,
            encdec_extra=enc, frontend=frontend_proj,
        )
        total = sum(counts.values())
        active = total - moe_total + moe_active
        counts["total"] = total
        counts["active"] = active
        return counts

    def n_params(self) -> int:
        return self.param_counts()["total"]

    def n_active_params(self) -> int:
        return self.param_counts()["active"]

    def scaled(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Input-shape cells (assigned shapes)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> Tuple[bool, str]:
    """Whether a (arch x shape) cell runs, with a reason when skipped."""
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: no sub-quadratic path at 512k (DESIGN.md)"
    return True, ""
