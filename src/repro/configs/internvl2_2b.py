"""internvl2-2b — InternViT frontend (stub) + InternLM2-1.8b backbone
[arXiv:2404.16821; hf].

Vision frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (batch, 256, 1024), linearly projected to d_model
and prepended to the token sequence (text length = seq_len - 256).
"""
from repro.configs.base import ElasticConfig, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    activation="swiglu",
    norm="rmsnorm",
    use_rope=True,
    frontend="vision_stub",
    frontend_seq=256,
    frontend_dim=1024,
    elastic=ElasticConfig(width_fractions=(0.5, 1.0), exit_layers=(12, 18)),
)
