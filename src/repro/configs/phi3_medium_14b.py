"""phi3-medium-14b — RoPE SwiGLU GQA [arXiv:2404.14219; unverified]."""
from repro.configs.base import ElasticConfig, ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    d_ff=17920,
    vocab_size=100352,
    activation="swiglu",
    norm="rmsnorm",
    use_rope=True,
    elastic=ElasticConfig(width_fractions=(0.5, 1.0), exit_layers=(20, 30)),
)
