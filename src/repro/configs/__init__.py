from repro.configs.base import (
    ElasticConfig,
    ModelConfig,
    MorphMode,
    SHAPES,
    SHAPE_BY_NAME,
    ShapeCell,
    cell_applicable,
)
from repro.configs.registry import ARCHS, get_config, list_archs, smoke_config

__all__ = [
    "ElasticConfig",
    "ModelConfig",
    "MorphMode",
    "SHAPES",
    "SHAPE_BY_NAME",
    "ShapeCell",
    "cell_applicable",
    "ARCHS",
    "get_config",
    "list_archs",
    "smoke_config",
]
