"""Architecture registry: ``--arch <id>`` lookup + reduced smoke variants."""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.configs.base import ElasticConfig, ModelConfig

from repro.configs.jamba_v0_1_52b import CONFIG as JAMBA
from repro.configs.whisper_base import CONFIG as WHISPER
from repro.configs.nemotron_4_340b import CONFIG as NEMOTRON
from repro.configs.phi3_medium_14b import CONFIG as PHI3
from repro.configs.tinyllama_1_1b import CONFIG as TINYLLAMA
from repro.configs.deepseek_67b import CONFIG as DEEPSEEK
from repro.configs.mamba2_370m import CONFIG as MAMBA2
from repro.configs.granite_moe_1b_a400m import CONFIG as GRANITE
from repro.configs.mixtral_8x22b import CONFIG as MIXTRAL
from repro.configs.internvl2_2b import CONFIG as INTERNVL

ARCHS: Dict[str, ModelConfig] = {
    cfg.name: cfg
    for cfg in (
        JAMBA, WHISPER, NEMOTRON, PHI3, TINYLLAMA,
        DEEPSEEK, MAMBA2, GRANITE, MIXTRAL, INTERNVL,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(name: str, *, seed_dims: int = 32) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests.

    Keeps the layer pattern / family structure (hybrid period, MoE routing,
    enc-dec split, frontend stub) while shrinking widths, depths, expert
    counts, and embedding tables.
    """
    cfg = get_config(name)
    d = seed_dims * 2  # d_model 64
    period = cfg.period
    n_groups = max(2, min(3, cfg.n_groups))
    kw = dict(
        name=cfg.name + "-smoke",
        n_layers=period * n_groups,
        d_model=d,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=2 if cfg.n_kv_heads else 0,
        head_dim=16 if cfg.n_heads else 0,
        d_ff=d * 2 if cfg.d_ff else 0,
        vocab_size=512,
        param_dtype="float32",
        dtype="float32",
        elastic=ElasticConfig(
            width_fractions=(0.5, 1.0),  # smoke kv heads = 2: finer slices invalid
            exit_layers=(max(1, n_groups // 2),),
        ),
    )
    if cfg.n_experts:
        kw.update(n_experts=4, top_k=min(2, cfg.top_k), moe_d_ff=d * 2, moe_group_size=64)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
    if cfg.is_encdec:
        kw.update(enc_layers=2, enc_seq=24)
    if cfg.frontend:
        kw.update(frontend_seq=8 if cfg.frontend == "vision_stub" else 24, frontend_dim=48)
    return dataclasses.replace(cfg, **kw)


def list_archs():
    return sorted(ARCHS)
