"""mamba2-370m — attention-free SSD (state-space duality) [arXiv:2405.21060; unverified]."""
from repro.configs.base import ElasticConfig, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,  # attention-free
    n_kv_heads=0,
    d_ff=0,  # pure mamba stack: no MLP sub-block
    vocab_size=50280,
    norm="rmsnorm",
    use_rope=False,
    layer_pattern=("ssm",),
    ssm_state=128,
    ssm_expand=2,
    ssm_conv=4,
    ssm_head_dim=64,
    ssm_ngroups=1,
    ssm_chunk=256,
    tie_embeddings=True,
    elastic=ElasticConfig(width_fractions=(0.5, 1.0), exit_layers=(24, 36)),
)
