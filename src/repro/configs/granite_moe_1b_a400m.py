"""granite-moe-1b-a400m — 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from repro.configs.base import ElasticConfig, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,  # per-expert hidden dim
    vocab_size=49155,
    activation="swiglu",
    norm="rmsnorm",
    use_rope=True,
    n_experts=32,
    top_k=8,
    moe_period=1,
    tie_embeddings=True,
    elastic=ElasticConfig(width_fractions=(0.5, 1.0), exit_layers=(12, 18)),
)
