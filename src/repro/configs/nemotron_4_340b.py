"""nemotron-4-340b — dense GQA, squared-ReLU MLP [arXiv:2402.16819; unverified]."""
from repro.configs.base import ElasticConfig, ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    activation="squared_relu",  # up/down MLP (2 matrices), per Nemotron-4
    norm="layernorm",
    use_rope=True,
    elastic=ElasticConfig(width_fractions=(0.5, 1.0), exit_layers=(48, 72)),
)
