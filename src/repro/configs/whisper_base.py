"""whisper-base — encoder-decoder audio backbone, conv frontend stubbed
[arXiv:2212.04356; unverified].

The modality frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (batch, 1500, 512). Shapes cells apply to the
decoder; the encoder length is fixed at 1500 (30s of audio at 50 fps).
"""
from repro.configs.base import ElasticConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,  # decoder layers
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    activation="gelu",
    norm="layernorm",
    use_rope=False,  # sinusoidal/learned absolute positions
    enc_layers=6,
    enc_seq=1500,
    frontend="audio_stub",
    frontend_seq=1500,
    frontend_dim=512,
    elastic=ElasticConfig(width_fractions=(0.5, 1.0), exit_layers=(3,)),
)
