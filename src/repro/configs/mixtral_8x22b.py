"""mixtral-8x22b — MoE 8 experts top-2, sliding-window attention [arXiv:2401.04088; hf].

The assignment specifies SWA; window 4096 (Mistral lineage). This is what makes
the arch sub-quadratic and eligible for the long_500k cell (rolling KV window).
"""
from repro.configs.base import ElasticConfig, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,  # per-expert hidden dim
    vocab_size=32768,
    activation="swiglu",
    norm="rmsnorm",
    use_rope=True,
    sliding_window=4096,
    n_experts=8,
    top_k=2,
    moe_period=1,  # every layer is MoE
    elastic=ElasticConfig(width_fractions=(0.5, 1.0), exit_layers=(28, 42)),
)
