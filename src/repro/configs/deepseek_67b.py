"""deepseek-67b — llama-arch dense [arXiv:2401.02954; hf]."""
from repro.configs.base import ElasticConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    activation="swiglu",
    norm="rmsnorm",
    use_rope=True,
    elastic=ElasticConfig(width_fractions=(0.5, 1.0), exit_layers=(48, 72)),
)
