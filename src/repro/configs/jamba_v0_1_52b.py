"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887; hf].

Period-8 layer pattern with attention at position 4 (1 attn : 7 mamba), MoE on
every other layer (moe_period=2). The 8-layer period is one scanned group, so
depth morphing exits at period boundaries (4 groups total).
"""
from repro.configs.base import ElasticConfig, ModelConfig

_PATTERN = ("ssm", "ssm", "ssm", "ssm", "attn", "ssm", "ssm", "ssm")

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    activation="swiglu",
    norm="rmsnorm",
    use_rope=False,  # jamba uses no positional encoding (mamba provides order)
    layer_pattern=_PATTERN,
    n_experts=16,
    top_k=2,
    moe_period=2,
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    ssm_head_dim=64,
    ssm_ngroups=1,
    ssm_chunk=256,
    elastic=ElasticConfig(width_fractions=(0.5, 1.0), exit_layers=(2, 3)),
)
