"""Data pipeline: deterministic sharded synthetic token streams + prefetch.

Every host process draws only its own shard of the global batch (keyed by
(seed, step, shard)), so the pipeline is reproducible across restarts and
elastic re-sharding — a requirement for fault-tolerant training (the restart
test asserts bit-identical batches after resume).

The synthetic task is a *learnable* language: a fixed random bigram
transition table (per seed) generates token streams, so CE loss has real
signal and DistillCycle subnet-vs-full comparisons are meaningful.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 64
    n_shards: int = 1
    shard: int = 0
    bigram_temperature: float = 1.0


class BigramTask:
    """Fixed random bigram LM over the config vocab (the learnable target)."""

    def __init__(self, vocab: int, seed: int, temperature: float = 1.0):
        rng = np.random.default_rng(seed)
        # sparse-ish logits: each token strongly prefers ~8 successors
        self.vocab = vocab
        self.n_next = min(8, vocab)
        self.succ = rng.integers(0, vocab, size=(vocab, self.n_next))
        self.temperature = temperature

    def sample(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        toks = np.empty((batch, seq + 1), np.int64)
        toks[:, 0] = rng.integers(0, self.vocab, size=batch)
        for t in range(seq):
            choice = rng.integers(0, self.n_next, size=batch)
            nxt = self.succ[toks[:, t], choice]
            # occasional uniform noise keeps entropy > 0
            noise = rng.random(batch) < 0.1
            nxt = np.where(noise, rng.integers(0, self.vocab, size=batch), nxt)
            toks[:, t + 1] = nxt
        return toks


def make_batch(cfg: ModelConfig, dc: DataConfig, step: int,
               task: Optional[BigramTask] = None) -> Dict[str, np.ndarray]:
    """Shard-local batch for ``step``.

    The *global* batch is generated from (seed, step) and each shard takes a
    row slice — so re-sharding (elastic scale up/down) never changes the
    global token stream, and restarts are bit-identical.
    """
    assert dc.global_batch % dc.n_shards == 0
    b = dc.global_batch // dc.n_shards
    rng = np.random.default_rng((dc.seed, step))
    task = task or BigramTask(cfg.vocab_size, dc.seed)
    text_len = dc.seq_len - (cfg.frontend_seq if cfg.frontend == "vision_stub" else 0)
    toks = task.sample(rng, dc.global_batch, text_len)
    lo, hi = dc.shard * b, (dc.shard + 1) * b
    batch = {
        "tokens": toks[lo:hi, :-1].astype(np.int32),
        "targets": toks[lo:hi, 1:].astype(np.int32),
    }
    if cfg.frontend == "vision_stub":
        batch["patches"] = rng.standard_normal(
            (dc.global_batch, cfg.frontend_seq, cfg.frontend_dim))[lo:hi].astype(np.float32)
    if cfg.is_encdec:
        batch["frames"] = rng.standard_normal(
            (dc.global_batch, cfg.enc_seq, cfg.frontend_dim))[lo:hi].astype(np.float32)
    return batch


class PrefetchIterator:
    """Background-thread prefetch of up to ``depth`` batches."""

    def __init__(self, cfg: ModelConfig, dc: DataConfig, start_step: int = 0,
                 depth: int = 2):
        self.cfg, self.dc = cfg, dc
        self.task = BigramTask(cfg.vocab_size, dc.seed)
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = make_batch(self.cfg, self.dc, step, self.task)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
