from repro.data.pipeline import BigramTask, DataConfig, PrefetchIterator, make_batch

__all__ = ["BigramTask", "DataConfig", "PrefetchIterator", "make_batch"]
