from repro.models.model import (
    cross_entropy,
    decode_step,
    forward,
    init_decode_cache,
    init_params,
    loss_fn,
    pos_kind,
    prefill,
    reset_cache_slot,
    reset_cache_slots,
    adopt_cache_slot,
)

__all__ = [
    "cross_entropy",
    "decode_step",
    "forward",
    "init_decode_cache",
    "init_params",
    "loss_fn",
    "pos_kind",
    "prefill",
    "reset_cache_slot",
    "reset_cache_slots",
    "adopt_cache_slot",
]
