from repro.models.model import (
    cross_entropy,
    decode_step,
    forward,
    init_decode_cache,
    init_params,
    loss_fn,
    pos_kind,
    prefill,
    reset_cache_slot,
    reset_cache_slots,
    adopt_cache_slot,
)
from repro.models.paged import (
    PagedLayout,
    adopt_paged_slot,
    copy_page,
    init_paged_cache,
    paged_view,
)

__all__ = [
    "cross_entropy",
    "decode_step",
    "forward",
    "init_decode_cache",
    "init_params",
    "loss_fn",
    "pos_kind",
    "prefill",
    "reset_cache_slot",
    "reset_cache_slots",
    "adopt_cache_slot",
    "PagedLayout",
    "adopt_paged_slot",
    "copy_page",
    "init_paged_cache",
    "paged_view",
]
