"""Top-k mixture-of-experts with capacity-based one-hot dispatch.

The dispatch follows the flaxformer/maxtext pattern: tokens are processed in
groups, assignments are prioritized choice-major (all first choices before
second choices), and tokens beyond an expert's capacity are dropped (their
combine weight is zero, so the residual path carries them — graceful, and the
FLOP count is proportional to capacity, which keeps the roofline honest about
*active* compute).

Width morphing (NeuroMorph) reduces ``top_k`` — the MoE analogue of the
paper's per-layer filter-count reduction.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init


def init_moe(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, e), dtype=jnp.float32),  # router in f32
        "wi": dense_init(ks[1], (e, d, f), in_axis=1, dtype=pd),
        "wo": dense_init(ks[2], (e, f, d), in_axis=1, dtype=pd),
    }
    if cfg.activation == "swiglu":
        p["wg"] = dense_init(ks[3], (e, d, f), in_axis=1, dtype=pd)
    return p


def _capacity(group: int, top_k: int, n_experts: int, factor: float) -> int:
    c = int(math.ceil(group * top_k / n_experts * factor))
    return max(4, -(-c // 4) * 4)  # round up to multiple of 4


def apply_moe(params, x, cfg: ModelConfig, top_k: Optional[int] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss). Routing per token group."""
    dt = x.dtype
    B, S, d = x.shape
    k = top_k or cfg.top_k
    e = cfg.n_experts
    T = B * S
    g = min(cfg.moe_group_size, T)
    if T % g:
        g = T  # fall back to one group (tiny smoke inputs)
    ng = T // g
    xt = x.reshape(ng, g, d)

    logits = jnp.einsum("sgd,de->sge", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)  # (ng, g, k)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    cap = _capacity(g, k, e, cfg.capacity_factor)
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)  # (ng, g, k, e)
    # choice-major priority: first choices of all tokens come first
    m_flat = onehot.transpose(0, 2, 1, 3).reshape(ng, k * g, e)
    pos = jnp.cumsum(m_flat, axis=1) * m_flat - m_flat  # 0-based slot per assignment
    keep = (pos < cap).astype(jnp.float32) * m_flat
    disp_flat = keep[..., None] * jax.nn.one_hot(
        pos.astype(jnp.int32), cap, dtype=jnp.float32)  # (ng,kg,e,cap)
    dispatch = disp_flat.reshape(ng, k, g, e, cap).transpose(0, 2, 1, 3, 4)  # (ng,g,k,e,cap)

    combine = jnp.einsum("sgkec,sgk->sgec", dispatch, gate_vals)  # (ng,g,e,cap)
    disp_any = jnp.sum(dispatch, axis=2)  # (ng,g,e,cap) in {0,1}

    xe = jnp.einsum("sgec,sgd->secd", disp_any.astype(dt), xt)  # (ng,e,cap,d)
    h = jnp.einsum("secd,edf->secf", xe, params["wi"].astype(dt),
                   preferred_element_type=jnp.float32).astype(dt)
    if "wg" in params:
        gg = jnp.einsum("secd,edf->secf", xe, params["wg"].astype(dt),
                        preferred_element_type=jnp.float32)
        h = jax.nn.silu(gg).astype(dt) * h
    elif cfg.activation == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(dt)
    ye = jnp.einsum("secf,efd->secd", h, params["wo"].astype(dt),
                    preferred_element_type=jnp.float32).astype(dt)
    y = jnp.einsum("sgec,secd->sgd", combine.astype(dt), ye)

    # Switch-style load balance aux: e * sum_e fraction_e * prob_e
    top1 = jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32)
    frac = jnp.mean(top1, axis=(0, 1))
    pmean = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac * pmean)
    return y.reshape(B, S, d), aux


def apply_moe_dense(params, x, cfg: ModelConfig, top_k: Optional[int] = None,
                    active_topk=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact dropless top-k MoE: compute every expert, combine sparse gates.

    Used on the decode path (token counts are tiny and every expert's weights
    are streamed from HBM regardless — the FLOP inflation is roofline-free)
    and as the no-drop oracle for capacity-dispatch tests.

    ``active_topk`` (scalar or per-batch (B,) int32) is the runtime width
    gate: the router still takes the full static top-k (shapes are fixed),
    but choices >= active_topk get zero gate weight *before* renormalization
    — identical math to slicing top_k, since top_k is sorted descending.
    """
    dt = x.dtype
    B, S, d = x.shape
    k = top_k or cfg.top_k
    e = cfg.n_experts
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)
    if active_topk is not None:
        at = jnp.asarray(active_topk, jnp.int32)
        choice = jnp.arange(k)
        keep = choice < (at[:, None, None] if at.ndim else at)
        gate_vals = jnp.where(keep, gate_vals, 0.0)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    gates = jnp.sum(jax.nn.one_hot(idx, e, dtype=jnp.float32) * gate_vals[..., None], axis=-2)

    h = jnp.einsum("bsd,edf->bsef", x, params["wi"].astype(dt),
                   preferred_element_type=jnp.float32).astype(dt)
    if "wg" in params:
        gg = jnp.einsum("bsd,edf->bsef", x, params["wg"].astype(dt),
                        preferred_element_type=jnp.float32)
        h = jax.nn.silu(gg).astype(dt) * h
    elif cfg.activation == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(dt)
    ye = jnp.einsum("bsef,efd->bsed", h, params["wo"].astype(dt),
                    preferred_element_type=jnp.float32)
    y = jnp.einsum("bsed,bse->bsd", ye, gates).astype(dt)

    top1 = jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32)
    aux = e * jnp.sum(jnp.mean(top1, axis=(0, 1)) * jnp.mean(probs, axis=(0, 1)))
    return y, aux
