"""Mamba2 / SSD (state-space duality) blocks — pure JAX chunked implementation.

The chunked SSD algorithm (arXiv:2405.21060) decomposes the linear recurrence
into intra-chunk dense (matmul-friendly — maps onto the MXU) and inter-chunk
state-passing terms. This file is the reference implementation used by the
model zoo and the oracle for ``repro.kernels.ssd_scan``.

Projection weights are stored *unpacked* (w_x, w_z, w_bc, w_dt) so NeuroMorph
width morphing can prefix-slice SSD heads without re-packing.

Recurrence convention (inclusive decay):
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t (x) x_t
    y_t = C_t . h_t + D * x_t
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import (apply_norm, apply_norm_masked, dense_init,
                                 matmul, morph_proj)
from repro.parallel.sharding import constrain


def init_ssm(key, cfg: ModelConfig):
    d = cfg.d_model
    d_in = cfg.ssm_d_inner
    nh, g, n, k = cfg.ssm_nheads, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_conv
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 9)
    # A init in [1, 16) (mamba2 default), dt bias ~ softplus^-1(dt) for dt in [1e-3, 1e-1]
    a = jax.random.uniform(ks[5], (nh,), jnp.float32, 1.0, 16.0)
    dt = jnp.exp(
        jax.random.uniform(ks[6], (nh,), jnp.float32) * (jnp.log(0.1) - jnp.log(0.001))
        + jnp.log(0.001)
    )
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "w_x": dense_init(ks[0], (d, d_in), dtype=pd),
        "w_z": dense_init(ks[1], (d, d_in), dtype=pd),
        "w_bc": dense_init(ks[2], (d, 2 * g * n), dtype=pd),
        "w_dt": dense_init(ks[3], (d, nh), dtype=pd),
        "conv_x_w": dense_init(ks[4], (d_in, k), in_axis=1, dtype=pd),
        "conv_x_b": jnp.zeros((d_in,), pd),
        "conv_bc_w": dense_init(ks[7], (2 * g * n, k), in_axis=1, dtype=pd),
        "conv_bc_b": jnp.zeros((2 * g * n,), pd),
        "A_log": jnp.log(a).astype(pd),
        "D": jnp.ones((nh,), pd),
        "dt_bias": dt_bias.astype(pd),
        "ssm_norm": {"scale": jnp.ones((d_in,), pd)},
        "out_proj": dense_init(ks[8], (d_in, d), dtype=pd),
    }


def _causal_conv(u, w, b, tail: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. u: (B,S,Cc), w: (Cc,K), b: (Cc,).

    If ``tail`` (B,K-1,Cc) is given it is prepended (decode/prefill chaining).
    Returns (y, new_tail).
    """
    B, S, Cc = u.shape
    K = w.shape[1]
    if tail is None:
        tail = jnp.zeros((B, K - 1, Cc), u.dtype)
    xt = jnp.concatenate([tail, u], axis=1)  # (B, S+K-1, Cc)
    # gather K shifted views and contract: y_t = sum_k w[:,k] * x_{t+k}
    views = jnp.stack([xt[:, k : k + S, :] for k in range(K)], axis=-1)  # (B,S,Cc,K)
    y = jnp.einsum("bsck,ck->bsc", views.astype(jnp.float32), w.astype(jnp.float32))
    y = (y + b.astype(jnp.float32)).astype(u.dtype)
    new_tail = xt[:, S:, :] if S >= K - 1 else xt[:, -(K - 1):, :]
    return y, new_tail


def ssd_chunked(x, dt, A, B_, C_, chunk: int):
    """Chunked SSD scan.

    x: (b, s, h, p) f32; dt: (b, s, h) f32 (post-softplus); A: (h,) f32 (<0);
    B_, C_: (b, s, g, n) f32 with g dividing h. Returns (y, final_state) where
    y: (b, s, h, p) and final_state: (b, h, p, n).
    """
    b, s, h, p = x.shape
    g, n = B_.shape[2], B_.shape[3]
    rep = h // g
    if s % chunk:
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S = x.shape[1]
    nc = S // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bh = jnp.repeat(B_.reshape(b, nc, chunk, g, n), rep, axis=3)  # (b,nc,Q,h,n)
    Ch = jnp.repeat(C_.reshape(b, nc, chunk, g, n), rep, axis=3)

    dA = dtc * A  # (b,nc,Q,h), negative
    dA_cs = jnp.cumsum(dA, axis=2)  # inclusive

    # intra-chunk: y_q += C_q . sum_{s<=q} exp(dA_cs[q]-dA_cs[s]) dt_s B_s x_s
    CB = jnp.einsum("bcqhn,bcshn->bchqs", Ch, Bh, preferred_element_type=jnp.float32)
    t = dA_cs.transpose(0, 1, 3, 2)  # (b,nc,h,Q)
    L = jnp.exp(t[..., :, None] - t[..., None, :])  # (b,nc,h,Q,Q)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(tri, L, 0.0)
    u = xc * dtc[..., None]  # (b,nc,Q,h,p)
    y_diag = jnp.einsum("bchqs,bcshp->bcqhp", CB * L, u, preferred_element_type=jnp.float32)

    # end-of-chunk states: sum_s exp(dA_cs[-1]-dA_cs[s]) dt_s B_s x_s
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # (b,nc,Q,h)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", Bh, decay_states * dtc, xc,
                        preferred_element_type=jnp.float32)

    # inter-chunk recurrence over nc
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])  # (b,nc,h)

    def scan_fn(h_prev, inp):
        st, dec = inp  # (b,h,p,n), (b,h)
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev  # emit state *before* this chunk

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_fn, h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b,nc,h,p,n)

    # contribution of carried state: y_q += exp(dA_cs[q]) C_q . state_prev
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Ch, prev_states, jnp.exp(dA_cs),
                       preferred_element_type=jnp.float32)

    y = (y_diag + y_off).reshape(b, S, h, p)[:, :s]
    return y, final_state


def ssd_reference(x, dt, A, B_, C_):
    """O(s) sequential reference (oracle for tests). Same signature/returns."""
    b, s, h, p = x.shape
    g, n = B_.shape[2], B_.shape[3]
    rep = h // g
    Bh = jnp.repeat(B_, rep, axis=2)
    Ch = jnp.repeat(C_, rep, axis=2)

    def step(state, inp):
        x_t, dt_t, b_t, c_t = inp  # (b,h,p), (b,h), (b,h,n), (b,h,n)
        decay = jnp.exp(dt_t * A)  # (b,h)
        upd = jnp.einsum("bhp,bhn->bhpn", x_t * dt_t[..., None], b_t)
        state = state * decay[..., None, None] + upd
        y_t = jnp.einsum("bhpn,bhn->bhp", state, c_t)
        return state, y_t

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          Bh.transpose(1, 0, 2, 3), Ch.transpose(1, 0, 2, 3))
    final, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3), final


def ssm_forward(params, x, cfg: ModelConfig, *, conv_tail=None, ssm_state=None,
                return_state: bool = False):
    """Full-sequence mamba2 block. x: (B,S,d). Returns (y, (conv_tail, state))."""
    dt_ = x.dtype
    nh = params["A_log"].shape[0]
    hp = cfg.ssm_head_dim
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    xs = matmul(x, params["w_x"], dt_)  # (B,S,d_in')
    z = matmul(x, params["w_z"], dt_)
    bc = matmul(x, params["w_bc"], dt_)  # (B,S,2gn)
    dt_raw = matmul(x, params["w_dt"], dt_)  # (B,S,nh)

    xs, x_tail = _causal_conv(xs, params["conv_x_w"][: nh * hp], params["conv_x_b"][: nh * hp],
                              None if conv_tail is None else conv_tail[0])
    bc, bc_tail = _causal_conv(bc, params["conv_bc_w"], params["conv_bc_b"],
                               None if conv_tail is None else conv_tail[1])
    xs = jax.nn.silu(xs.astype(jnp.float32))
    bc = jax.nn.silu(bc.astype(jnp.float32))
    B_ = bc[..., : g * n].reshape(bc.shape[0], bc.shape[1], g, n)
    C_ = bc[..., g * n :].reshape(bc.shape[0], bc.shape[1], g, n)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xs.reshape(xs.shape[0], xs.shape[1], nh, hp)
    if ssm_state is not None:
        # prefix state from a previous segment: fold in via off-diagonal term
        # (decode path uses ssm_decode_step; segment chaining rarely needed)
        raise NotImplementedError("segment chaining handled by ssd_chunked caller")
    y, final_state = ssd_chunked(xh, dt, A, B_, C_, cfg.ssm_chunk)
    y = y + params["D"].astype(jnp.float32)[:, None] * xh
    y = y.reshape(xs.shape[0], xs.shape[1], nh * hp)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = apply_norm({"scale": params["ssm_norm"]["scale"][: nh * hp]}, y.astype(dt_), cfg)
    out = matmul(y, params["out_proj"], dt_)
    if return_state:
        return out, ((x_tail, bc_tail), final_state.astype(jnp.float32))
    return out, None


def init_ssm_cache(cfg: ModelConfig, batch: int, nh: Optional[int] = None, dtype=jnp.float32):
    nh = nh or cfg.ssm_nheads
    hp, g, n, k = cfg.ssm_head_dim, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_conv
    return {
        "conv_x": jnp.zeros((batch, k - 1, nh * hp), dtype),
        "conv_bc": jnp.zeros((batch, k - 1, 2 * g * n), dtype),
        "state": jnp.zeros((batch, nh, hp, n), jnp.float32),
    }


def ssm_decode_step(params, x, cache, cfg: ModelConfig, active=None):
    """One-token decode. x: (B,1,d). Returns (y, new_cache).

    ``active`` (dict with "d_inner"/"ssm_heads", scalars or per-batch (B,))
    runtime-gates the head dimension: the x/z/dt projections zero columns
    beyond each slot's active width, the z-gate multiplies inactive channels
    (which pick up conv bias) back to exact zero, the gated RMSNorm divides
    by the *active* channel count, and the output projection's contraction
    skips inactive channels. Inactive heads still carry (bounded) garbage in
    ``state`` — it is unread, and slot re-admission zeroes it.
    """
    dt_ = x.dtype
    nh = params["A_log"].shape[0]
    hp = cfg.ssm_head_dim
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    a_in = active.get("d_inner") if active else None
    # pin the channel layout under a mesh (see decode_specs): the scan math
    # below must see whole heads per shard, not the projection's column split
    xs = constrain(morph_proj(x, params["w_x"], active_n=a_in), "decode_ssm")
    z = constrain(morph_proj(x, params["w_z"], active_n=a_in), "decode_ssm")
    bc = matmul(x, params["w_bc"], dt_)  # B/C groups are never width-gated
    dt_raw = morph_proj(x, params["w_dt"],
                        active_n=active.get("ssm_heads") if active else None)

    xs, x_tail = _causal_conv(xs, params["conv_x_w"][: nh * hp], params["conv_x_b"][: nh * hp],
                              cache["conv_x"])
    bc, bc_tail = _causal_conv(bc, params["conv_bc_w"], params["conv_bc_b"], cache["conv_bc"])
    xs = jax.nn.silu(xs.astype(jnp.float32))[:, 0]  # (B, d_in)
    bc = jax.nn.silu(bc.astype(jnp.float32))[:, 0]
    B_ = jnp.repeat(bc[..., : g * n].reshape(-1, g, n), nh // g, axis=1)  # (B,h,n)
    C_ = jnp.repeat(bc[..., g * n :].reshape(-1, g, n), nh // g, axis=1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)[:, 0] + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xs.reshape(-1, nh, hp)

    decay = jnp.exp(dt * A)  # (B,h)
    upd = jnp.einsum("bhp,bhn->bhpn", xh * dt[..., None], B_)
    state = cache["state"] * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", state, C_) + params["D"].astype(jnp.float32)[:, None] * xh
    y = (y.reshape(-1, 1, nh * hp) * jax.nn.silu(z.astype(jnp.float32)))
    norm = {"scale": params["ssm_norm"]["scale"][: nh * hp]}
    if a_in is None:
        y = apply_norm(norm, y.astype(dt_), cfg)
    else:
        y = apply_norm_masked(norm, y.astype(dt_), cfg, a_in)
    out = morph_proj(y, params["out_proj"], active_k=a_in)
    return out, {"conv_x": x_tail, "conv_bc": bc_tail, "state": state}


def _conv_step_tails(tail0, u):
    """Per-step conv tails: tails[:, j] = last K-1 inputs after consuming
    u[:, :j+1]. tail0: (B, K-1, C); u: (B, S, C). Returns (B, S, K-1, C)."""
    S = u.shape[1]
    xt = jnp.concatenate([tail0, u], axis=1)  # (B, S+K-1, C)
    return jnp.stack([xt[:, 1 + o : 1 + o + S, :]
                      for o in range(tail0.shape[1])], axis=2)


def ssm_verify_step(params, x, cache, cfg: ModelConfig, active=None):
    """Speculative verify pass: score S positions in one launch.

    Same math as S chained ``ssm_decode_step`` calls (conv chaining off the
    cached tails, sequential state recurrence), but the cache is READ only:
    instead of committing, the per-step recurrent state and conv tails are
    returned stacked over positions so ``models.model.commit_verify`` can
    select the state after exactly ``n_accepted + 1`` consumed tokens.

    Returns (y (B, S, d), candidates) with candidates holding per-step
    ``conv_x`` / ``conv_bc`` tails (B, S, K-1, C) and ``state``
    (B, S, nh, hp, n) — entry j is the value AFTER consuming token j.
    """
    dt_ = x.dtype
    B, S, _ = x.shape
    nh = params["A_log"].shape[0]
    hp = cfg.ssm_head_dim
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    a_in = active.get("d_inner") if active else None
    xs = constrain(morph_proj(x, params["w_x"], active_n=a_in), "decode_ssm")
    z = constrain(morph_proj(x, params["w_z"], active_n=a_in), "decode_ssm")
    bc = matmul(x, params["w_bc"], dt_)
    dt_raw = morph_proj(x, params["w_dt"],
                        active_n=active.get("ssm_heads") if active else None)

    xs_conv, _ = _causal_conv(xs, params["conv_x_w"][: nh * hp],
                              params["conv_x_b"][: nh * hp], cache["conv_x"])
    bc_conv, _ = _causal_conv(bc, params["conv_bc_w"], params["conv_bc_b"],
                              cache["conv_bc"])
    x_tails = _conv_step_tails(cache["conv_x"], xs)
    bc_tails = _conv_step_tails(cache["conv_bc"], bc)

    xs_f = jax.nn.silu(xs_conv.astype(jnp.float32))  # (B, S, d_in)
    bc_f = jax.nn.silu(bc_conv.astype(jnp.float32))
    B_ = jnp.repeat(bc_f[..., : g * n].reshape(B, S, g, n), nh // g, axis=2)
    C_ = jnp.repeat(bc_f[..., g * n :].reshape(B, S, g, n), nh // g, axis=2)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B, S, nh)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xs_f.reshape(B, S, nh, hp)

    def step(state, inp):
        x_t, dt_t, b_t, c_t = inp  # (B,h,p), (B,h), (B,h,n), (B,h,n)
        decay = jnp.exp(dt_t * A)
        upd = jnp.einsum("bhp,bhn->bhpn", x_t * dt_t[..., None], b_t)
        state = state * decay[..., None, None] + upd
        y_t = jnp.einsum("bhpn,bhn->bhp", state, c_t)
        return state, (y_t, state)

    xs_seq = (xh.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
              B_.transpose(1, 0, 2, 3), C_.transpose(1, 0, 2, 3))
    _, (ys, states) = jax.lax.scan(step, cache["state"], xs_seq)
    ys = ys.transpose(1, 0, 2, 3)  # (B, S, h, p)
    states = states.transpose(1, 0, 2, 3, 4)  # (B, S, h, p, n)

    y = ys + params["D"].astype(jnp.float32)[:, None] * xh
    y = y.reshape(B, S, nh * hp) * jax.nn.silu(z.astype(jnp.float32))
    norm = {"scale": params["ssm_norm"]["scale"][: nh * hp]}
    if a_in is None:
        y = apply_norm(norm, y.astype(dt_), cfg)
    else:
        y = apply_norm_masked(norm, y.astype(dt_), cfg, a_in)
    out = morph_proj(y, params["out_proj"], active_k=a_in)
    return out, {"conv_x": x_tails, "conv_bc": bc_tails, "state": states}


def _path_conv(u, w, b, tail, paths):
    """Per-node causal conv along each tree node's ancestor path.

    u: (B, N, C) per-node conv inputs in tree order; ``paths`` is the static
    tuple of root-to-node index paths. For node q the conv consumes the
    cached tail (B, K-1, C) followed by the inputs along q's path — exactly
    the window ``depth(q) + 1`` chained ``_causal_conv`` decode steps down
    that branch would have seen. Returns (y (B, N, C) conv outputs at each
    node, tails (B, N, K-1, C) the per-node post-consume tails).
    """
    K = w.shape[1]
    ys, tails = [], []
    for path in paths:
        ext = jnp.concatenate([tail, u[:, list(path), :]], axis=1)
        win = ext[:, -K:, :]  # len(ext) = K-1 + depth+1 >= K always
        y = jnp.einsum("bkc,ck->bc", win.astype(jnp.float32),
                       w.astype(jnp.float32))
        ys.append((y + b.astype(jnp.float32)).astype(u.dtype))
        tails.append(ext[:, -(K - 1):, :])
    return jnp.stack(ys, axis=1), jnp.stack(tails, axis=1)


def ssm_verify_tree(params, x, cache, cfg: ModelConfig, tree, active=None):
    """Token-tree verify pass: score all tree nodes in one launch.

    Same math as chaining ``ssm_decode_step`` down every root-to-leaf branch
    (conv windows and recurrent state both follow the ancestor path, read
    from the committed cache, never written), evaluated for the whole tree
    at once: node q's state is ``decay_q * state_parent(q) + upd_q`` with
    the root chaining off ``cache["state"]``. ``tree`` carries the static
    topology (``paths``, ``parents`` — see runtime.speculative.TreeTopology).

    Returns (y (B, N, d), candidates) with per-node ``conv_x`` / ``conv_bc``
    tails (B, N, K-1, C) and ``state`` (B, N, nh, hp, n) — entry q is the
    value AFTER consuming the path ending at node q, so a path-index gather
    plus ``commit_verify``'s one-hot select lands the accepted branch.
    """
    dt_ = x.dtype
    B, N, _ = x.shape
    nh = params["A_log"].shape[0]
    hp = cfg.ssm_head_dim
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    a_in = active.get("d_inner") if active else None
    xs = constrain(morph_proj(x, params["w_x"], active_n=a_in), "decode_ssm")
    z = constrain(morph_proj(x, params["w_z"], active_n=a_in), "decode_ssm")
    bc = matmul(x, params["w_bc"], dt_)
    dt_raw = morph_proj(x, params["w_dt"],
                        active_n=active.get("ssm_heads") if active else None)

    xs_conv, x_tails = _path_conv(xs, params["conv_x_w"][: nh * hp],
                                  params["conv_x_b"][: nh * hp],
                                  cache["conv_x"], tree.paths)
    bc_conv, bc_tails = _path_conv(bc, params["conv_bc_w"],
                                   params["conv_bc_b"], cache["conv_bc"],
                                   tree.paths)

    xs_f = jax.nn.silu(xs_conv.astype(jnp.float32))  # (B, N, d_in)
    bc_f = jax.nn.silu(bc_conv.astype(jnp.float32))
    B_ = jnp.repeat(bc_f[..., : g * n].reshape(B, N, g, n), nh // g, axis=2)
    C_ = jnp.repeat(bc_f[..., g * n :].reshape(B, N, g, n), nh // g, axis=2)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B, N, nh)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xs_f.reshape(B, N, nh, hp)

    decay = jnp.exp(dt * A)  # (B, N, h)
    states = []
    for node, par in enumerate(tree.parents):
        prev = cache["state"] if par < 0 else states[par]
        upd = jnp.einsum("bhp,bhn->bhpn",
                         xh[:, node] * dt[:, node][..., None], B_[:, node])
        states.append(prev * decay[:, node][..., None, None] + upd)
    states = jnp.stack(states, axis=1)  # (B, N, h, p, n)
    ys = jnp.einsum("bshpn,bshn->bshp", states, C_)

    y = ys + params["D"].astype(jnp.float32)[:, None] * xh
    y = y.reshape(B, N, nh * hp) * jax.nn.silu(z.astype(jnp.float32))
    norm = {"scale": params["ssm_norm"]["scale"][: nh * hp]}
    if a_in is None:
        y = apply_norm(norm, y.astype(dt_), cfg)
    else:
        y = apply_norm_masked(norm, y.astype(dt_), cfg, a_in)
    out = morph_proj(y, params["out_proj"], active_k=a_in)
    return out, {"conv_x": x_tails, "conv_bc": bc_tails, "state": states}


def ssm_tree_level(params, x, cache, carry, cfg: ModelConfig, *, parents,
                   active=None):
    """One tree-draft LEVEL of the SSM recurrence: each frontier node
    advances ONE step from its parent's carried state.

    x: (B, nf, d) frontier embeddings; ``carry`` holds per-node post-consume
    values of already-processed nodes — ``conv_x``/``conv_bc`` tails
    (B, Nc, K-1, C) and ``state`` (B, Nc, nh, hp, n); ``parents`` is the
    static (nf,) carry-row index of each frontier node's parent (-1 = chain
    off the committed ``cache``, i.e. the root level). Bit-identical to the
    frontier rows of ``ssm_verify_tree``: a node's conv window is the last
    K entries of [parent tail, own input], exactly the tail of the full
    path window, and the state recurrence reads the identical parent state.

    Returns (y (B, nf, d), rows) with per-node ``conv_x``/``conv_bc`` tails
    (B, nf, K-1, C) and ``state`` (B, nf, nh, hp, n) — ready to write into
    the carry at the frontier rows.
    """
    dt_ = x.dtype
    B, nf, _ = x.shape
    nh = params["A_log"].shape[0]
    hp = cfg.ssm_head_dim
    g, n = cfg.ssm_ngroups, cfg.ssm_state
    a_in = active.get("d_inner") if active else None
    xs = constrain(morph_proj(x, params["w_x"], active_n=a_in), "decode_ssm")
    z = constrain(morph_proj(x, params["w_z"], active_n=a_in), "decode_ssm")
    bc = matmul(x, params["w_bc"], dt_)
    dt_raw = morph_proj(x, params["w_dt"],
                        active_n=active.get("ssm_heads") if active else None)

    if int(parents[0]) < 0:  # root level: nf == 1, chain off the cache
        x_tails_par = cache["conv_x"][:, None]
        bc_tails_par = cache["conv_bc"][:, None]
        states_par = cache["state"][:, None]
    else:
        pidx = np.asarray(parents, np.int32)
        x_tails_par = carry["conv_x"][:, pidx]
        bc_tails_par = carry["conv_bc"][:, pidx]
        states_par = carry["state"][:, pidx]

    def _node_conv(u, w, b, tails):
        """u: (B, nf, C); tails: (B, nf, K-1, C) parent post-consume tails.
        Window per node = [parent tail, own input] — the last K entries of
        the full ancestor-path window ``_path_conv`` materializes."""
        ext = jnp.concatenate([tails.astype(u.dtype), u[:, :, None, :]], 2)
        y = jnp.einsum("bqkc,ck->bqc", ext.astype(jnp.float32),
                       w.astype(jnp.float32))
        y = (y + b.astype(jnp.float32)).astype(u.dtype)
        return y, ext[:, :, 1:, :]

    xs_conv, x_tails = _node_conv(xs, params["conv_x_w"][: nh * hp],
                                  params["conv_x_b"][: nh * hp], x_tails_par)
    bc_conv, bc_tails = _node_conv(bc, params["conv_bc_w"],
                                   params["conv_bc_b"], bc_tails_par)

    xs_f = jax.nn.silu(xs_conv.astype(jnp.float32))  # (B, nf, d_in)
    bc_f = jax.nn.silu(bc_conv.astype(jnp.float32))
    B_ = jnp.repeat(bc_f[..., : g * n].reshape(B, nf, g, n), nh // g, axis=2)
    C_ = jnp.repeat(bc_f[..., g * n :].reshape(B, nf, g, n), nh // g, axis=2)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,nf,nh)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xs_f.reshape(B, nf, nh, hp)

    decay = jnp.exp(dt * A)  # (B, nf, h)
    upd = jnp.einsum("bqhp,bqhn->bqhpn", xh * dt[..., None], B_)
    states = states_par.astype(jnp.float32) * decay[..., None, None] + upd
    ys = jnp.einsum("bqhpn,bqhn->bqhp", states, C_)

    y = ys + params["D"].astype(jnp.float32)[:, None] * xh
    y = y.reshape(B, nf, nh * hp) * jax.nn.silu(z.astype(jnp.float32))
    norm = {"scale": params["ssm_norm"]["scale"][: nh * hp]}
    if a_in is None:
        y = apply_norm(norm, y.astype(dt_), cfg)
    else:
        y = apply_norm_masked(norm, y.astype(dt_), cfg, a_in)
    out = morph_proj(y, params["out_proj"], active_k=a_in)
    return out, {"conv_x": x_tails, "conv_bc": bc_tails, "state": states}
