"""Unified model zoo: init / forward / prefill / decode for every assigned family.

Layer stacks are organized as *groups*: one group = one period of the layer
pattern (8 layers for jamba, 1 for dense archs). Group parameters are stacked
along a leading axis of size ``cfg.n_groups`` and executed with ``lax.scan``
— this is what keeps 96-layer models compiling fast and what gives NeuroMorph
its depth-morph boundaries (exits live between groups).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.parallel import sharding as _sh

Params = Dict
Cache = Dict


def pos_kind(cfg: ModelConfig) -> str:
    if cfg.use_rope:
        return "rope"
    if cfg.family in ("ssm", "hybrid"):
        return "none"
    return "sinusoidal"


def _remat_wrap(fn, policy: str):
    if policy == "none":
        return fn
    pol = {
        "full": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }[policy]
    return jax.checkpoint(fn, policy=pol)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ModelConfig, p: int, *, cross: bool = False) -> Params:
    kind = cfg.layer_kind(p)
    is_moe = cfg.layer_is_moe(p)
    ks = jax.random.split(key, 6)
    out: Params = {"norm1": L.init_norm(cfg)}
    if kind == "attn":
        out["attn"] = L.init_attention(ks[0], cfg)
    else:
        out["ssm"] = SSM.init_ssm(ks[0], cfg)
    if cross:
        out["norm_cross"] = L.init_norm(cfg)
        out["cross"] = L.init_attention(ks[1], cfg, cross=True)
    if is_moe:
        out["norm2"] = L.init_norm(cfg)
        out["moe"] = MOE.init_moe(ks[2], cfg)
    elif cfg.d_ff:
        out["norm2"] = L.init_norm(cfg)
        out["mlp"] = L.init_mlp(ks[2], cfg)
    return out


def _init_stack(key, cfg: ModelConfig, n_groups: int, *, cross: bool = False) -> Params:
    def one_group(k):
        ks = jax.random.split(k, cfg.period)
        return {f"pos{p}": _init_layer(ks[p], cfg, p, cross=cross) for p in range(cfg.period)}

    keys = jax.random.split(key, n_groups)
    return jax.vmap(one_group)(keys)


def init_params(key, cfg: ModelConfig) -> Params:
    pd = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    v = cfg.padded_vocab()
    params: Params = {
        "embed": L.dense_init(ks[0], (v, cfg.d_model), in_axis=-1, dtype=pd),
        "final_norm": L.init_norm(cfg),
        "stack": _init_stack(ks[1], cfg, cfg.n_groups, cross=cfg.is_encdec),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(ks[2], (cfg.d_model, v), dtype=pd)
    if cfg.elastic.exit_layers and cfg.elastic.dedicated_exit_norm:
        params["exit_norms"] = {
            f"g{g}": L.init_norm(cfg) for g in cfg.elastic.exit_layers
        }
    if cfg.is_encdec:
        enc_cfg = cfg.scaled(layer_pattern=("attn",), n_layers=cfg.enc_layers,
                             n_experts=0, top_k=0, use_rope=False, enc_layers=0)
        params["encoder"] = {
            "stack": _init_stack(ks[3], enc_cfg, cfg.enc_layers),
            "final_norm": L.init_norm(cfg),
        }
    if cfg.frontend:
        params["frontend_proj"] = L.dense_init(ks[4], (cfg.frontend_dim, cfg.d_model), dtype=pd)
    return params


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def _group_fwd(group_params, h, cfg: ModelConfig, positions, *, enc_out=None,
               enc_positions=None, causal=True, want_cache=False, cache_extra=0):
    """Run one period of layers. Returns (h, aux, cache_or_None)."""
    aux = jnp.zeros((), jnp.float32)
    caches = {}
    for p in range(cfg.period):
        lp = group_params[f"pos{p}"]
        kind = cfg.layer_kind(p)
        hn = L.apply_norm(lp["norm1"], h, cfg)
        if kind == "attn":
            mix, (k_, v_) = L.mha(lp["attn"], hn, cfg, positions, causal=causal)
            if want_cache:
                caches[f"pos{p}"] = _pack_kv_cache(k_, v_, cfg, cache_extra)
        else:
            need_state = want_cache
            mix, st = SSM.ssm_forward(lp["ssm"], hn, cfg, return_state=need_state)
            if want_cache:
                (x_tail, bc_tail), state = st
                caches[f"pos{p}"] = {"conv_x": x_tail, "conv_bc": bc_tail, "state": state}
        h = h + mix
        if cfg.is_encdec:
            hn = L.apply_norm(lp["norm_cross"], h, cfg)
            mix, (ck, cv) = L.mha(lp["cross"], hn, cfg, positions, kv_x=enc_out,
                                  kv_positions=enc_positions, causal=False)
            if want_cache:
                caches[f"pos{p}"]["cross_k"] = ck
                caches[f"pos{p}"]["cross_v"] = cv
            h = h + mix
        if cfg.layer_is_moe(p):
            hn = L.apply_norm(lp["norm2"], h, cfg)
            moe_fn = MOE.apply_moe_dense if cfg.moe_impl == "dense" else MOE.apply_moe
            y, a = moe_fn(lp["moe"], hn, cfg)
            aux = aux + a
            h = h + y
        elif cfg.d_ff:
            hn = L.apply_norm(lp["norm2"], h, cfg)
            h = h + L.apply_mlp(lp["mlp"], hn, cfg)
    return h, aux, (caches if want_cache else None)


def _pack_kv_cache(k, v, cfg: ModelConfig, extra: int = 0):
    """Full-seq K/V -> decode cache layout.

    Sliding-window archs use a rolling buffer of exactly ``window`` slots
    (token at absolute position t lives at slot t %% window — matches
    ``mha_decode``). Full-attention archs get ``extra`` free slots appended
    so subsequent decode steps have room.
    """
    S = k.shape[1]
    w = cfg.sliding_window
    if w:
        eff = min(S, w)
        slots = (jnp.arange(S - eff, S) % w).astype(jnp.int32)
        kc = jnp.zeros((k.shape[0], w) + k.shape[2:], k.dtype).at[:, slots].set(k[:, -eff:])
        vc = jnp.zeros((v.shape[0], w) + v.shape[2:], v.dtype).at[:, slots].set(v[:, -eff:])
        k, v = kc, vc
    elif extra:
        pad = [(0, 0)] * k.ndim
        pad[1] = (0, extra)
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    if cfg.kv_quant:
        kq, ks_ = L.quantize_kv(k)
        vq, vs = L.quantize_kv(v)
        return {"k": kq, "v": vq, "k_scale": ks_, "v_scale": vs}
    return {"k": k, "v": v}


def _scan_groups(stack, h, cfg: ModelConfig, positions, *, start: int, stop: int,
                 remat: str = "none", enc_out=None, enc_positions=None,
                 want_cache: bool = False, cache_extra: int = 0):
    """Scan groups [start, stop). Returns (h, aux, caches(G-slice) or None)."""
    sub = jax.tree_util.tree_map(lambda a: a[start:stop], stack)

    def body(carry, group_params):
        h, aux = carry
        h, a, cache = _group_fwd(group_params, h, cfg, positions, enc_out=enc_out,
                                 enc_positions=enc_positions, want_cache=want_cache,
                                 cache_extra=cache_extra)
        h = _sh.constrain(h, "residual")  # SP: seq -> model between groups
        return (h, aux + a), cache

    body = _remat_wrap(body, remat)
    (h, aux), caches = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)), sub)
    return h, aux, caches


def _embed_inputs(params, batch, cfg: ModelConfig):
    """Token (+frontend) embedding. Returns (h, positions, enc_out, enc_pos)."""
    dt = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    h = params["embed"][tokens].astype(dt)
    enc_out = enc_pos = None
    if cfg.frontend == "vision_stub":
        patches = batch["patches"].astype(dt)  # (B, P, fd)
        ph = L.matmul(patches, params["frontend_proj"], dt)
        h = jnp.concatenate([ph, h], axis=1)
    S = h.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    if pos_kind(cfg) == "sinusoidal":
        h = h + L.sinusoidal_pos(positions, cfg.d_model).astype(dt)
    if cfg.is_encdec:
        frames = batch["frames"].astype(dt)  # (B, enc_seq, fd)
        eh = L.matmul(frames, params["frontend_proj"], dt)
        enc_pos = jnp.arange(eh.shape[1], dtype=jnp.int32)
        eh = eh + L.sinusoidal_pos(enc_pos, cfg.d_model).astype(dt)
        ecfg = cfg.scaled(layer_pattern=("attn",), n_layers=cfg.enc_layers,
                          n_experts=0, top_k=0, use_rope=False, sliding_window=0,
                          enc_layers=0)
        (eh, _), _ = jax.lax.scan(
            lambda c, gp: ((_group_fwd(gp, c[0], ecfg, enc_pos, causal=False)[0], c[1]), None),
            (eh, jnp.zeros((), jnp.float32)), params["encoder"]["stack"])
        enc_out = L.apply_norm(params["encoder"]["final_norm"], eh, cfg)
    return h, positions, enc_out, enc_pos


def _logits(params, h, cfg: ModelConfig, norm_params) -> jnp.ndarray:
    h = L.apply_norm(norm_params, h, cfg)
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    return L.matmul(h, w, h.dtype)


def forward(params, batch, cfg: ModelConfig, *, depth: Optional[int] = None,
            collect_exits: Tuple[int, ...] = (), remat: str = "none"):
    """Full-sequence forward.

    Returns (outputs, aux) where outputs maps "final" -> logits and
    "exit_g{i}" -> logits for each requested exit group.
    """
    depth = depth if depth is not None else cfg.n_groups
    h, positions, enc_out, enc_pos = _embed_inputs(params, batch, cfg)
    boundaries = sorted([g for g in collect_exits if g < depth]) + [depth]
    outputs = {}
    aux = jnp.zeros((), jnp.float32)
    start = 0
    for b in boundaries:
        if b > start:
            h, a, _ = _scan_groups(params["stack"], h, cfg, positions, start=start,
                                   stop=b, remat=remat, enc_out=enc_out,
                                   enc_positions=enc_pos)
            aux = aux + a
        if b < depth:
            np_ = params.get("exit_norms", {}).get(f"g{b}", params["final_norm"])
            outputs[f"exit_g{b}"] = _logits(params, h, cfg, np_)
        start = b
    norm_p = params["final_norm"]
    if depth < cfg.n_groups:
        norm_p = params.get("exit_norms", {}).get(f"g{depth}", norm_p)
    outputs["final"] = _logits(params, h, cfg, norm_p)
    return outputs, aux


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def cross_entropy(logits, targets, cfg: ModelConfig, loss_mask=None):
    """Next-token CE with padded-vocab masking. logits: (B,S,Vp), targets: (B,S)."""
    v = cfg.vocab_size
    lg = logits.astype(jnp.float32)
    pad = lg.shape[-1] - v
    if pad:
        neg = jnp.full(lg.shape[:-1] + (pad,), -1e9, jnp.float32)
        lg = jnp.concatenate([lg[..., :v], neg], axis=-1)
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if loss_mask is None:
        loss_mask = jnp.ones_like(nll)
    return jnp.sum(nll * loss_mask) / jnp.maximum(jnp.sum(loss_mask), 1.0)


def loss_fn(params, batch, cfg: ModelConfig, *, depth=None, remat: str = "none",
            aux_weight: float = 0.01):
    """Standard LM loss (teacher phase / plain training)."""
    outs, aux = forward(params, batch, cfg, depth=depth, remat=remat)
    logits = outs["final"]
    targets = batch["targets"]
    mask = batch.get("loss_mask")
    if cfg.frontend == "vision_stub":  # logits cover patches + text; text only
        P = cfg.frontend_seq
        logits = logits[:, P:]
    loss = cross_entropy(logits, targets, cfg, mask)
    return loss + aux_weight * aux, {"ce": loss, "moe_aux": aux}


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------


def init_decode_cache(cfg: ModelConfig, batch: int, capacity: int, *,
                      per_slot: bool = False) -> Cache:
    """Zeroed cache with room for ``capacity`` tokens.

    ``per_slot=True`` gives every batch slot its own position counter (shape
    ``(batch,)`` instead of a scalar) so independent requests can occupy
    slots at different sequence offsets — the continuous-batching layout.
    """
    dt = jnp.dtype(cfg.dtype)

    def one_layer(p: int):
        kind = cfg.layer_kind(p)
        if kind == "attn":
            c = L.init_kv_cache(cfg, batch, capacity, dt)
        else:
            c = SSM.init_ssm_cache(cfg, batch, dtype=dt)
        if cfg.is_encdec:
            c["cross_k"] = jnp.zeros((batch, cfg.enc_seq, cfg.n_kv_heads, cfg.head_dim), dt)
            c["cross_v"] = jnp.zeros((batch, cfg.enc_seq, cfg.n_kv_heads, cfg.head_dim), dt)
        return c

    stack = {f"pos{p}": jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (cfg.n_groups,) + a.shape), one_layer(p))
        for p in range(cfg.period)}
    pos = jnp.zeros((batch,) if per_slot else (), jnp.int32)
    return {"pos": pos, "stack": stack}


_RECURRENT_CACHE_KEYS = ("conv_x", "conv_bc", "state")


def reset_cache_slot(cache: Cache, slot) -> Cache:
    """Rewind one batch slot of a per-slot cache for a freshly admitted request.

    Zeroes the slot's recurrent state (SSM conv tails / state, which carry
    across tokens unconditionally) and its position counter. Attention KV
    needs no zeroing: ``mha_decode`` masks cache entries at idx > pos, so a
    rewound position hides the previous occupant's keys. Jit this once per
    cache structure (with the cache donated) — ``slot`` is a traced scalar,
    so re-admission never recompiles or copies.

    Thin wrapper over ``reset_cache_slots`` with a one-hot mask — one reset
    implementation serves both the scalar and the batched call sites (and
    both cache layouts: pooled attention leaves are not recurrent keys, so
    the paged cache resets identically).
    """
    n_slots = cache["pos"].shape[0]
    return reset_cache_slots(cache, jnp.arange(n_slots) == slot)


def reset_cache_slots(cache: Cache, mask) -> Cache:
    """Batched ``reset_cache_slot``: rewind every slot where ``mask`` is True.

    ``mask`` is a (n_slots,) bool vector, so one jitted call (with the cache
    donated) covers an entire admission burst — admission cost no longer
    scales with burst size, and under a mesh the whole rewind is a single
    device-side launch with no gathers. Same semantics as the scalar version:
    recurrent state is zeroed, attention KV is left in place (position
    masking hides it), position counters rewind to 0.
    """
    mask = jnp.asarray(mask)

    def leaf(k, a):
        if k not in _RECURRENT_CACHE_KEYS:
            return a
        m = mask.reshape((1, mask.shape[0]) + (1,) * (a.ndim - 2))
        return jnp.where(m, jnp.zeros((), a.dtype), a)

    stack = {pname: {k: leaf(k, a) for k, a in layer.items()}
             for pname, layer in cache["stack"].items()}
    return {"pos": jnp.where(mask, 0, cache["pos"]), "stack": stack}


def adopt_cache_slot(cache: Cache, pre: Cache, slot) -> Cache:
    """Copy slot ``slot`` of a prefilled engine-layout cache into ``cache``.

    ``pre`` comes from ``prefill(per_slot=True, slot=..., n_slots=...)`` and
    is layout-identical to ``cache``; only the prefilled slot's lane (all
    keys — KV, recurrent state, position) is taken, so the adoption is one
    jitted scatter per cache structure with ``slot`` traced. The remaining
    slots of ``cache`` are untouched.
    """
    stack = jax.tree_util.tree_map(
        lambda full, new: full.at[:, slot].set(new[:, slot].astype(full.dtype)),
        cache["stack"], pre["stack"])
    return {"pos": cache["pos"].at[slot].set(pre["pos"][slot]), "stack": stack}


def _group_decode(group_params, group_cache, h, pos, cfg: ModelConfig,
                  active=None, pages=None, page_size=0, fused=False):
    new_cache = {}
    for p in range(cfg.period):
        lp = group_params[f"pos{p}"]
        cp = group_cache[f"pos{p}"]
        kind = cfg.layer_kind(p)
        hn = L.apply_norm(lp["norm1"], h, cfg)
        nc = dict(cp)
        if kind == "attn":
            self_keys = {k: v for k, v in cp.items() if not k.startswith("cross_")}
            mix, upd = L.mha_decode(lp["attn"], hn, self_keys, pos, cfg,
                                    active=active, pages=pages,
                                    page_size=page_size, fused=fused)
            nc.update(upd)
        else:
            self_keys = {k: cp[k] for k in ("conv_x", "conv_bc", "state")}
            mix, upd = SSM.ssm_decode_step(lp["ssm"], hn, self_keys, cfg,
                                           active=active)
            nc.update(upd)
        h = h + mix
        if cfg.is_encdec:
            hn = L.apply_norm(lp["norm_cross"], h, cfg)
            mix, _ = L.mha_decode(lp["cross"], hn,
                                  {"k": cp["cross_k"], "v": cp["cross_v"]}, pos, cfg,
                                  cross=True, active=active)
            h = h + mix
        if cfg.layer_is_moe(p):
            # decode always uses the exact dropless path (see apply_moe_dense)
            hn = L.apply_norm(lp["norm2"], h, cfg)
            y, _ = MOE.apply_moe_dense(
                lp["moe"], hn, cfg,
                active_topk=active.get("top_k") if active else None)
            h = h + y
        elif cfg.d_ff:
            hn = L.apply_norm(lp["norm2"], h, cfg)
            h = h + L.apply_mlp(lp["mlp"], hn, cfg,
                                active_ff=active.get("d_ff") if active else None)
        new_cache[f"pos{p}"] = nc
    return h, new_cache


def decode_step(params, cache, tokens, cfg: ModelConfig, *, depth: Optional[int] = None,
                active=None, pages=None, page_size=0, fused=False):
    """One-token decode. tokens: (B, 1). Returns (logits (B,1,Vp), new_cache).

    ``pages`` / ``page_size`` switch the attention cache to the block-paged
    layout (``models.paged``): ``pages`` is the traced (B, P) int32 page
    table a paged ``cache``'s pooled K/V leaves are read and written
    through. SSM leaves are per-slot dense either way.

    ``active`` is the runtime width-morph operand (see
    ``elastic.active_widths_batch``): a dict of active inner-dim sizes,
    scalars or per-slot (B,) vectors, applied over FULL params and a
    full-width cache. Depth stays a compile-time bound (it changes the scan
    trip count); width is just data — one executable per depth serves every
    width, and a batch may mix widths across slots.

    The cache stack rides through the group scan as a CARRY updated with
    slice-sized dynamic updates (never as stacked scan outputs): stacked ys
    force XLA to rebuild the full multi-GB cache buffer every iteration,
    which dominated decode HBM traffic in the baseline dry-run (§Perf B2).
    """
    depth = depth if depth is not None else cfg.n_groups
    dt = jnp.dtype(cfg.dtype)
    pos = cache["pos"]  # scalar, or (B,) per-slot positions
    h = params["embed"][tokens].astype(dt)
    if pos_kind(cfg) == "sinusoidal":
        spos = pos[:, None] if pos.ndim == 1 else jnp.full((1,), pos, jnp.int32)
        h = h + L.sinusoidal_pos(spos, cfg.d_model).astype(dt)

    stack_p = jax.tree_util.tree_map(lambda a: a[:depth], params["stack"])
    full_stack = cache["stack"]

    def body(carry, xs):
        h, cache_stack = carry
        g_idx, gp = xs
        gc = jax.tree_util.tree_map(
            lambda a: jax.lax.dynamic_index_in_dim(a, g_idx, 0, keepdims=False),
            cache_stack)
        h, nc = _group_decode(gp, gc, h, pos, cfg, active=active,
                              pages=pages, page_size=page_size, fused=fused)
        h = _sh.constrain(h, "residual")  # mesh serving: pin the decode stream
        cache_stack = jax.tree_util.tree_map(
            lambda full, new: jax.lax.dynamic_update_index_in_dim(
                full, new.astype(full.dtype), g_idx, 0),
            cache_stack, nc)
        return (h, cache_stack), None

    (h, full_stack), _ = jax.lax.scan(
        body, (h, full_stack), (jnp.arange(depth, dtype=jnp.int32), stack_p))

    norm_p = params["final_norm"]
    if depth < cfg.n_groups:
        norm_p = params.get("exit_norms", {}).get(f"g{depth}", norm_p)
    logits = _logits(params, h, cfg, norm_p)
    return logits, {"pos": pos + 1, "stack": full_stack}


def _group_verify(group_params, group_cache, h, pos, cfg: ModelConfig,
                  active=None, tree=None, pages=None, page_size=0,
                  fused=False):
    """One period of layers over S speculative positions (read-only cache).

    Mirrors ``_group_decode`` but scores ``h`` (B, S, d) at absolute positions
    ``pos .. pos+S-1`` without writing the cache; per-layer write candidates
    (new KV, per-step SSM state/tails) are returned for ``commit_verify``.
    With ``tree`` (a static topology — see ``verify_tree``) the S positions
    are the flattened token tree instead of a linear window: attention gets
    the ancestor-mask bias, the SSM recurrence follows parent pointers.
    """
    cand = {}
    for p in range(cfg.period):
        lp = group_params[f"pos{p}"]
        cp = group_cache[f"pos{p}"]
        kind = cfg.layer_kind(p)
        hn = L.apply_norm(lp["norm1"], h, cfg)
        if kind == "attn":
            self_keys = {k: v for k, v in cp.items() if not k.startswith("cross_")}
            mix, c = L.mha_verify(
                lp["attn"], hn, self_keys, pos, cfg, active=active,
                node_depth=None if tree is None else tree.depths,
                tree_bias=None if tree is None else tree.ancestor_bias,
                pages=pages, page_size=page_size, fused=fused)
        else:
            self_keys = {k: cp[k] for k in ("conv_x", "conv_bc", "state")}
            if tree is None:
                mix, c = SSM.ssm_verify_step(lp["ssm"], hn, self_keys, cfg,
                                             active=active)
            else:
                mix, c = SSM.ssm_verify_tree(lp["ssm"], hn, self_keys, cfg,
                                             tree, active=active)
        cand[f"pos{p}"] = c
        h = h + mix
        if cfg.layer_is_moe(p):
            hn = L.apply_norm(lp["norm2"], h, cfg)
            y, _ = MOE.apply_moe_dense(
                lp["moe"], hn, cfg,
                active_topk=active.get("top_k") if active else None)
            h = h + y
        elif cfg.d_ff:
            hn = L.apply_norm(lp["norm2"], h, cfg)
            h = h + L.apply_mlp(lp["mlp"], hn, cfg,
                                active_ff=active.get("d_ff") if active else None)
    return h, cand


def verify_step(params, cache, tokens, cfg: ModelConfig, *,
                depth: Optional[int] = None, active=None, pages=None,
                page_size=0, fused=False):
    """Speculative-decoding verifier: score S = K+1 positions in ONE pass.

    ``tokens`` is (B, S): the last committed token of each slot followed by
    its K draft tokens. The per-slot ``cache`` (positions ``pos`` (B,)) is
    read but NEVER written — the pass is side-effect free, so any acceptance
    count can be committed afterwards. Returns ``(logits, pending)``:
    ``logits`` (B, S, Vp) scores every position (``logits[:, j]`` is the
    model's next-token distribution after consuming ``tokens[:, :j+1]``,
    exactly what ``j+1`` chained ``decode_step`` calls would produce), and
    ``pending`` is the rollback-safe write set — pass it with a *traced*
    per-slot ``n_accepted`` to ``commit_verify`` to advance each slot by
    ``n_accepted + 1`` tokens via ``jnp.where``-masked cache writes: no host
    round-trip, and one executable serves every acceptance count.

    ``depth`` / ``active`` match ``decode_step``: depth is the compile-time
    scan bound (exit-head logits for shallow depths), width stays runtime
    per-slot data. Encoder-decoder / frontend archs are not supported (their
    decode path needs non-token operands the speculative loop doesn't carry).
    """
    if cfg.is_encdec or cfg.frontend:
        raise NotImplementedError("verify_step supports token-only decoders")
    depth = depth if depth is not None else cfg.n_groups
    dt = jnp.dtype(cfg.dtype)
    pos = cache["pos"]
    if pos.ndim != 1:
        raise ValueError("verify_step needs a per-slot cache (pos of shape (B,))")
    B, S = tokens.shape
    if cfg.sliding_window and S > cfg.sliding_window:
        # commit_verify's rolling scatter would map two window positions to
        # one buffer slot (undefined scatter winner) — bound K at the window
        raise ValueError(f"verify window of {S} positions exceeds the "
                         f"sliding window ({cfg.sliding_window}); use a "
                         f"draft length K <= window - 1")
    h = params["embed"][tokens].astype(dt)
    if pos_kind(cfg) == "sinusoidal":
        qpos = pos[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
        h = h + L.sinusoidal_pos(qpos, cfg.d_model).astype(dt)

    stack_p = jax.tree_util.tree_map(lambda a: a[:depth], params["stack"])
    stack_c = jax.tree_util.tree_map(lambda a: a[:depth], cache["stack"])

    def body(h, xs):
        gp, gc = xs
        h, cand = _group_verify(gp, gc, h, pos, cfg, active=active,
                                pages=pages, page_size=page_size, fused=fused)
        h = _sh.constrain(h, "residual")
        return h, cand

    h, cands = jax.lax.scan(body, h, (stack_p, stack_c))

    norm_p = params["final_norm"]
    if depth < cfg.n_groups:
        norm_p = params.get("exit_norms", {}).get(f"g{depth}", norm_p)
    logits = _logits(params, h, cfg, norm_p)
    return logits, {"stack": cands}


def verify_tree(params, cache, tokens, cfg: ModelConfig, *, tree,
                depth: Optional[int] = None, active=None, pages=None,
                page_size=0, fused=False):
    """Token-tree verifier: score a whole candidate tree in ONE pass.

    ``tokens`` is (B, N): the flattened token tree in BFS level order, node 0
    the last committed token of each slot, every other node a drafted
    candidate continuing its parent. ``tree`` is the static topology (duck-
    typed — see ``runtime.speculative.TreeTopology``): ``depths`` map nodes
    to absolute positions ``pos + depth``, ``ancestor_bias`` restricts each
    node's attention among the new keys to its own root path (position
    masking cannot separate sibling branches at equal depth), ``paths`` /
    ``parents`` drive the SSM conv windows and state recurrence down each
    branch. The per-slot cache is read, never written — the pass is side-
    effect free, so ANY root-to-leaf path can be committed afterwards.

    Returns ``(logits, pending)``: ``logits`` (B, N, Vp) where row j is the
    model's next-token distribution after consuming the root-to-j path
    (exactly what chained ``decode_step`` calls down that branch would
    produce), and ``pending`` the per-NODE write candidates — gather the
    accepted path with ``commit_verify(..., path_nodes=...)`` to advance
    each slot by a traced ``n_accepted + 1`` tokens.

    ``depth`` / ``active`` match ``decode_step``. The same executable also
    powers non-destructive tree DRAFTING: scored at a shallow exit depth, it
    expands the tree level by level without ever copying the committed cache
    into a scan carry.
    """
    if cfg.is_encdec or cfg.frontend:
        raise NotImplementedError("verify_tree supports token-only decoders")
    depth = depth if depth is not None else cfg.n_groups
    dt = jnp.dtype(cfg.dtype)
    pos = cache["pos"]
    if pos.ndim != 1:
        raise ValueError("verify_tree needs a per-slot cache (pos of shape (B,))")
    B, N = tokens.shape
    if N != tree.n_nodes:
        raise ValueError(f"tokens carry {N} nodes, topology has {tree.n_nodes}")
    if cfg.sliding_window and tree.n_levels + 1 > cfg.sliding_window:
        # commit_verify's rolling scatter would alias buffer slots
        raise ValueError(f"tree of depth {tree.n_levels} exceeds the sliding "
                         f"window ({cfg.sliding_window}); bound the tree "
                         f"depth at window - 1")
    h = params["embed"][tokens].astype(dt)
    if pos_kind(cfg) == "sinusoidal":
        qpos = pos[:, None] + jnp.asarray(tree.depths, jnp.int32)[None, :]
        h = h + L.sinusoidal_pos(qpos, cfg.d_model).astype(dt)

    stack_p = jax.tree_util.tree_map(lambda a: a[:depth], params["stack"])
    stack_c = jax.tree_util.tree_map(lambda a: a[:depth], cache["stack"])

    def body(h, xs):
        gp, gc = xs
        h, cand = _group_verify(gp, gc, h, pos, cfg, active=active, tree=tree,
                                pages=pages, page_size=page_size, fused=fused)
        h = _sh.constrain(h, "residual")
        return h, cand

    h, cands = jax.lax.scan(body, h, (stack_p, stack_c))

    norm_p = params["final_norm"]
    if depth < cfg.n_groups:
        norm_p = params.get("exit_norms", {}).get(f"g{depth}", norm_p)
    logits = _logits(params, h, cfg, norm_p)
    return logits, {"stack": cands}


def _group_tree_level(group_params, group_cache, group_carry, h, pos,
                      cfg: ModelConfig, *, level, tree, active=None,
                      pages=None, page_size=0):
    """One period of layers over one tree-draft level's frontier.

    Mirrors ``_group_verify`` restricted to the frontier rows: attention
    scores the frontier against the committed cache plus the K/V carried
    from earlier levels (``layers.mha_tree_level``), the SSM recurrence
    advances each frontier node one step from its parent's carried state
    (``ssm.ssm_tree_level``). Returns (h, rows) where ``rows`` holds each
    layer's new carry rows for the frontier.
    """
    rows = {}
    f0, f1 = tree.level_nodes(level)
    for p in range(cfg.period):
        lp = group_params[f"pos{p}"]
        cp = group_cache[f"pos{p}"]
        cr = group_carry[f"pos{p}"]
        kind = cfg.layer_kind(p)
        hn = L.apply_norm(lp["norm1"], h, cfg)
        if kind == "attn":
            self_keys = {k: v for k, v in cp.items() if not k.startswith("cross_")}
            mix, r = L.mha_tree_level(
                lp["attn"], hn, self_keys, pos, cfg, cr, level=level,
                carry_depths=tree.depths[:f1],
                bias=tree.ancestor_bias[f0:f1, :f1], active=active,
                pages=pages, page_size=page_size)
        else:
            self_keys = {k: cp[k] for k in ("conv_x", "conv_bc", "state")}
            mix, r = SSM.ssm_tree_level(lp["ssm"], hn, self_keys, cr, cfg,
                                        parents=tree.parents[f0:f1],
                                        active=active)
        rows[f"pos{p}"] = r
        h = h + mix
        if cfg.layer_is_moe(p):
            hn = L.apply_norm(lp["norm2"], h, cfg)
            y, _ = MOE.apply_moe_dense(
                lp["moe"], hn, cfg,
                active_topk=active.get("top_k") if active else None)
            h = h + y
        elif cfg.d_ff:
            hn = L.apply_norm(lp["norm2"], h, cfg)
            h = h + L.apply_mlp(lp["mlp"], hn, cfg,
                                active_ff=active.get("d_ff") if active else None)
    return h, rows


def tree_carry_nodes(tree) -> int:
    """Carry rows the KV-carrying tree draft allocates = nodes it processes
    per launch: every node except the last level's (leaf logits are never
    needed — children are only drafted for non-leaf levels)."""
    if tree.n_levels == 0:
        return 1
    return tree.level_nodes(tree.n_levels - 1)[1]


def init_tree_draft_carry(cfg: ModelConfig, batch: int, tree,
                          depth: Optional[int] = None) -> Cache:
    """Zeroed per-node carry for ``draft_tree_level`` (shape mirrors the
    cache stack, depth groups only, ``tree_carry_nodes`` rows per node axis).

    Attention layers carry round-tripped K/V rows; SSM layers carry
    post-consume conv tails and recurrent state. The carry is O(n_nodes)
    per layer — allocating it is what lets the draft drop the committed
    cache from its scan state entirely.
    """
    depth = depth if depth is not None else cfg.n_groups
    nc = tree_carry_nodes(tree)
    dt = jnp.dtype(cfg.dtype)

    def one_layer(p: int):
        if cfg.layer_kind(p) == "attn":
            return {
                "k": jnp.zeros((batch, nc, cfg.n_kv_heads, cfg.head_dim), dt),
                "v": jnp.zeros((batch, nc, cfg.n_kv_heads, cfg.head_dim), dt),
            }
        kk = cfg.ssm_conv
        d_in = cfg.ssm_nheads * cfg.ssm_head_dim
        return {
            "conv_x": jnp.zeros((batch, nc, kk - 1, d_in), dt),
            "conv_bc": jnp.zeros(
                (batch, nc, kk - 1, 2 * cfg.ssm_ngroups * cfg.ssm_state), dt),
            "state": jnp.zeros(
                (batch, nc, cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state),
                jnp.float32),
        }

    stack = {f"pos{p}": jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (depth,) + a.shape), one_layer(p))
        for p in range(cfg.period)}
    return {"stack": stack}


def draft_tree_level(params, cache, carry, tokens_lvl, cfg: ModelConfig, *,
                     tree, level: int, depth: Optional[int] = None,
                     active=None, pages=None, page_size=0):
    """Score ONE level of a draft token tree, carrying KV forward.

    ``tokens_lvl`` is (B, nf): the frontier tokens at ``level`` (level 0 is
    the root — the last committed token). The committed per-slot ``cache``
    is READ ONLY and never rides a scan carry; everything the deeper levels
    need is written to ``carry`` (from ``init_tree_draft_carry``), whose
    per-layer rows cover processed nodes in BFS order. Together with
    earlier levels this reproduces ``verify_tree``'s frontier rows
    bit-exactly while touching each node position exactly once — the draft
    cost drops from O(sum-of-level-prefix-sizes) to O(n_nodes) positions.

    Returns (logits (B, nf, Vp), new_carry).
    """
    if cfg.is_encdec or cfg.frontend:
        raise NotImplementedError("draft_tree_level supports token-only decoders")
    depth = depth if depth is not None else cfg.n_groups
    dt = jnp.dtype(cfg.dtype)
    pos = cache["pos"]
    if pos.ndim != 1:
        raise ValueError("draft_tree_level needs a per-slot cache (pos (B,))")
    B, nf = tokens_lvl.shape
    f0, f1 = tree.level_nodes(level)
    if f1 - f0 != nf:
        raise ValueError(f"level {level} frontier is {f1 - f0} nodes, "
                         f"tokens carry {nf}")
    h = params["embed"][tokens_lvl].astype(dt)
    if pos_kind(cfg) == "sinusoidal":
        qpos = pos[:, None] + jnp.full((nf,), level, jnp.int32)[None, :]
        h = h + L.sinusoidal_pos(qpos, cfg.d_model).astype(dt)

    stack_p = jax.tree_util.tree_map(lambda a: a[:depth], params["stack"])
    stack_c = jax.tree_util.tree_map(lambda a: a[:depth], cache["stack"])

    def body(h, xs):
        gp, gc, gcar = xs
        h, rows = _group_tree_level(gp, gc, gcar, h, pos, cfg, level=level,
                                    tree=tree, active=active, pages=pages,
                                    page_size=page_size)
        h = _sh.constrain(h, "residual")
        return h, rows

    h, rows = jax.lax.scan(body, h, (stack_p, stack_c, carry["stack"]))

    norm_p = params["final_norm"]
    if depth < cfg.n_groups:
        norm_p = params.get("exit_norms", {}).get(f"g{depth}", norm_p)
    logits = _logits(params, h, cfg, norm_p)
    new_stack = jax.tree_util.tree_map(
        lambda full, r: full.at[:, :, f0:f1].set(r.astype(full.dtype)),
        carry["stack"], rows)
    return logits, {"stack": new_stack}


def commit_verify(cache, pending, n_accepted, cfg: ModelConfig,
                  path_nodes=None, pages=None, page_size=0) -> Cache:
    """Advance each slot by ``n_accepted + 1`` tokens from a verify pass.

    ``pending`` comes from ``verify_step`` over S positions; ``n_accepted``
    is a traced (B,) int32 in [0, S-1] — the count of accepted draft tokens
    per slot. Attention K/V candidates are scattered with a
    ``jnp.where(j <= n_accepted, new, old)`` mask (rejected positions keep
    the previous buffer contents, which the advanced position counter then
    masks — and which sliding-window buffers must not clobber); SSM state
    and conv tails take the per-step candidate at index ``n_accepted``
    (exact one-hot selection). Cache groups beyond the verify depth are
    untouched. Commit is pure jnp over traced operands: one executable
    serves every acceptance pattern.

    ``path_nodes`` generalizes the commit to token trees: a traced (B, L)
    array of ``verify_tree`` node indices along each slot's accepted
    root-to-leaf path (entry 0 the root, entries past ``n_accepted`` any
    valid pad). Every pending leaf is first gathered along its node axis by
    the path — after which the accepted branch IS a linear window and the
    masked scatter / one-hot select below applies unchanged.

    With ``pages`` (traced (B, P) int32 table; see ``models.paged``) the
    attention scatter resolves each target position to its physical
    (page, offset) through the table; rejected lanes still write the old
    values back, so rolled-back positions leave the pool untouched — the
    host then frees the tail pages speculation reached past the commit.
    """
    pos = cache["pos"]  # (B,) committed-token counts before this launch
    n_accepted = jnp.asarray(n_accepted, jnp.int32)
    stack = cache["stack"]
    pend = pending["stack"]
    if path_nodes is not None:
        path = jnp.asarray(path_nodes, jnp.int32)  # (B, L)

        def gather_path(leaf):  # (d, B, N, ...) -> (d, B, L, ...)
            idx = path.reshape((1,) + path.shape + (1,) * (leaf.ndim - 3))
            idx = jnp.broadcast_to(idx, (leaf.shape[0],) + path.shape
                                   + leaf.shape[3:])
            return jnp.take_along_axis(leaf, idx, axis=2)

        pend = jax.tree_util.tree_map(gather_path, pend)
    first = jax.tree_util.tree_leaves(pend)[0]
    d, B, S = first.shape[0], first.shape[1], first.shape[2]
    j = jnp.arange(S, dtype=jnp.int32)
    acc = j[None, :] <= n_accepted[:, None]  # (B, S) commit mask
    onehot = (j[None, :] == n_accepted[:, None]).astype(jnp.float32)  # (B, S)
    batch_ix = jnp.arange(B)

    def scatter_kv(full, new):
        """full: (G, B, Sc, ...) dense, or (G, n_pages, page_size, ...) paged;
        new: (d, B, S, ...) — masked scatter at the slots positions
        pos..pos+S-1 map to (rolling for sliding windows)."""
        tgt = pos[:, None] + j[None, :]
        sub = full[:d]
        m = acc.reshape((1, B, S) + (1,) * (new.ndim - 3))
        if pages is not None:
            Sv = pages.shape[1] * page_size
            slot = jnp.mod(tgt, Sv) if cfg.sliding_window else jnp.minimum(tgt, Sv - 1)
            pg = slot // page_size  # (B, S) logical page per position
            phys = jnp.take_along_axis(pages, pg, axis=1)
            off = slot - pg * page_size
            old = sub[:, phys, off]  # (d, B, S, ...)
            vals = jnp.where(m, new.astype(full.dtype), old)
            sub = sub.at[:, phys, off].set(vals)
        else:
            Sc = full.shape[2]
            slot = jnp.mod(tgt, Sc) if cfg.sliding_window else jnp.minimum(tgt, Sc - 1)
            old = sub[:, batch_ix[:, None], slot]  # (d, B, S, ...)
            vals = jnp.where(m, new.astype(full.dtype), old)
            sub = sub.at[:, batch_ix[:, None], slot].set(vals)
        return jnp.concatenate([sub, full[d:]], axis=0)

    def select_step(full, new):
        """full: (G, B, ...); new: (d, B, S, ...) — take candidate n_accepted."""
        oh = onehot.reshape((1, B, S) + (1,) * (new.ndim - 3))
        sel = jnp.sum(new.astype(jnp.float32) * oh, axis=2)
        return jnp.concatenate([sel.astype(full.dtype), full[d:]], axis=0)

    new_stack = {}
    for pname, layer in stack.items():
        pc = pend[pname]
        nl = dict(layer)
        if "k" in pc:  # attention: candidates are raw K/V
            if "k_scale" in layer:
                kq, ks_ = L.quantize_kv(pc["k"])
                vq, vs = L.quantize_kv(pc["v"])
                nl["k"] = scatter_kv(layer["k"], kq)
                nl["v"] = scatter_kv(layer["v"], vq)
                nl["k_scale"] = scatter_kv(layer["k_scale"], ks_)
                nl["v_scale"] = scatter_kv(layer["v_scale"], vs)
            else:
                nl["k"] = scatter_kv(layer["k"], pc["k"])
                nl["v"] = scatter_kv(layer["v"], pc["v"])
        else:  # ssm: per-step recurrent candidates
            for key in ("conv_x", "conv_bc", "state"):
                nl[key] = select_step(layer[key], pc[key])
        new_stack[pname] = nl
    return {"pos": pos + n_accepted + 1, "stack": new_stack}


def prefill(params, batch, cfg: ModelConfig, *, remat: str = "none",
            cache_extra: int = 0, per_slot: bool = False,
            slot: Optional[int] = None, n_slots: Optional[int] = None,
            depth: Optional[int] = None):
    """Process a full prompt; returns (last-position logits, decode cache).

    ``cache_extra`` appends free KV slots so decode can continue past the
    prompt (the prefill_32k dry-run cell uses 0: cache of exactly seq_len).

    ``per_slot=True`` returns the continuous-batching layout (positions are a
    ``(B,)`` vector, one per batch slot). Passing ``slot`` (with ``n_slots``)
    additionally scatters a *batch-1* prompt's state into slot ``slot`` of an
    ``n_slots``-wide zeroed cache — the result is layout-identical to
    ``init_decode_cache(cfg, n_slots, S + cache_extra, per_slot=True)``, so a
    serving engine can adopt a prefilled prompt directly into one of its
    slots instead of feeding it token by token. ``slot`` may be a traced
    scalar: one compiled prefill per prompt length serves every slot.

    ``depth`` truncates the prompt pass at a depth-morph boundary, matching
    ``decode_step(depth=...)``: logits come from the exit head, cache groups
    beyond ``depth`` are zero (never scanned by that depth's executable).
    """
    depth = depth if depth is not None else cfg.n_groups
    h, positions, enc_out, enc_pos = _embed_inputs(params, batch, cfg)
    S = h.shape[1]
    h, aux, caches = _scan_groups(params["stack"], h, cfg, positions, start=0,
                                  stop=depth, remat=remat, enc_out=enc_out,
                                  enc_positions=enc_pos, want_cache=True,
                                  cache_extra=cache_extra)
    if depth < cfg.n_groups:  # pad the group stack back to engine layout
        caches = jax.tree_util.tree_map(
            lambda a: jnp.concatenate(
                [a, jnp.zeros((cfg.n_groups - depth,) + a.shape[1:], a.dtype)]),
            caches)
    norm_p = params["final_norm"]
    if depth < cfg.n_groups:
        norm_p = params.get("exit_norms", {}).get(f"g{depth}", norm_p)
    logits = _logits(params, h[:, -1:], cfg, norm_p)
    B = h.shape[0]
    if not per_slot:
        if slot is not None:
            raise ValueError("slot requires per_slot=True")
        return logits, {"pos": jnp.full((), S, jnp.int32), "stack": caches}
    if slot is None:
        return logits, {"pos": jnp.full((B,), S, jnp.int32), "stack": caches}
    if B != 1:
        raise ValueError(f"slot scatter needs a batch-1 prompt, got B={B}")
    ns = n_slots or 1
    # cache leaves are (n_groups, B, ...): widen axis 1 to the slot count
    stack = jax.tree_util.tree_map(
        lambda a: jnp.zeros((a.shape[0], ns) + a.shape[2:], a.dtype)
        .at[:, slot].set(a[:, 0]), caches)
    pos = jnp.zeros((ns,), jnp.int32).at[slot].set(S)
    return logits, {"pos": pos, "stack": stack}
