"""Core transformer layers: norms, positions, MLPs, GQA attention.

Everything is functional: ``init_*`` builds a param subtree, ``apply`` style
functions consume (params, inputs). Activations run in ``cfg.dtype``; params
are stored in ``cfg.param_dtype``. All matmuls accumulate in f32
(``preferred_element_type``) — the TPU MXU native mode.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.parallel.sharding import constrain

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _dtype(name: str):
    return jnp.dtype(name)


def dense_init(key, shape, in_axis: int = 0, scale: float = 1.0, dtype=jnp.float32):
    """Truncated-normal fan-in init (maxtext-style)."""
    fan_in = shape[in_axis] if in_axis >= 0 else int(np.prod(shape[:-1]))
    std = scale / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def matmul(x, w, dtype):
    if _BF16_GRAD_MATMUL:
        return _matmul_bf16g(x.astype(dtype), w.astype(dtype)).astype(dtype)
    return jax.lax.dot_general(
        x, w.astype(dtype), (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(dtype)


def morph_proj(x, w, active_n=None, active_k=None):
    """Width-gated projection on the decode hot path (NeuroMorph clock gate).

    Routes through ``kernels.morph_matmul`` (impl="auto": tile-skipping
    Pallas on TPU, fused masked dot elsewhere). Output columns >= active_n
    are exactly zero; contraction rows >= active_k contribute nothing.
    ``active_n`` / ``active_k`` may be per-batch ``(B,)`` vectors — batch
    slots running *different* width modes share this single projection.
    x: (B, S, d); w: (d, N).
    """
    from repro.kernels import morph_matmul as _mm  # local: keep layers import-light

    if active_n is None and active_k is None:
        return matmul(x, w, x.dtype)
    return _mm(x, w.astype(x.dtype), active_n, active_k, impl="auto")


# --- bf16-cotangent matmul (beyond-paper §Perf lever) -----------------------
#
# The default transpose rule leaves dW in f32 and GSPMD reduces it over the
# token axes *in f32* (2x wire). This custom VJP downcasts dW to the weight
# dtype immediately after the backward dot, so the cross-shard reduction
# happens at bf16. Enabled via ``use_bf16_grad_matmul`` (dry-run knob).

_BF16_GRAD_MATMUL = False


def set_bf16_grad_matmul(on: bool) -> None:
    global _BF16_GRAD_MATMUL
    _BF16_GRAD_MATMUL = on


@jax.custom_vjp
def _matmul_bf16g(x, w):
    return jax.lax.dot_general(x, w, (((x.ndim - 1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _matmul_bf16g_fwd(x, w):
    return _matmul_bf16g(x, w), (x, w)


def _matmul_bf16g_bwd(res, dy):
    x, w = res
    dy = dy.astype(x.dtype)
    dx = jax.lax.dot_general(dy, w, (((dy.ndim - 1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32).astype(x.dtype)
    # contract all leading (token) dims of x against dy. The dot's result
    # type IS the cross-shard reduction dtype under GSPMD (a later convert
    # cannot be hoisted above the psum without changing semantics), so emit
    # bf16 directly — the industry-standard bf16 gradient reduction.
    lead = tuple(range(x.ndim - 1))
    dw = jax.lax.dot_general(x, dy, ((lead, lead), ((), ())),
                             preferred_element_type=w.dtype)
    return dx, dw.astype(w.dtype)


_matmul_bf16g.defvjp(_matmul_bf16g_fwd, _matmul_bf16g_bwd)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    pd = _dtype(cfg.param_dtype)
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), pd), "bias": jnp.zeros((d,), pd)}
    return {"scale": jnp.ones((d,), pd)}


def apply_norm(params, x, cfg: ModelConfig, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    if "bias" in params:  # layernorm
        mean = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
        y = (x - mean) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:  # rmsnorm
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    return y.astype(dt)


def apply_norm_masked(params, x, cfg: ModelConfig, n_active, eps: float = 1e-6):
    """RMSNorm whose mean-square spans only the first ``n_active`` channels.

    The runtime-width morph path guarantees x is exactly zero beyond
    ``n_active``, so the full-width sum-of-squares equals the active-prefix
    sum; only the divisor changes. ``n_active``: scalar or per-batch (B,).
    """
    assert "bias" not in params, "masked norm is rmsnorm-only"
    dt = x.dtype
    xf = x.astype(jnp.float32)
    n = jnp.asarray(n_active, jnp.float32)
    if n.ndim:
        n = n.reshape(n.shape + (1,) * (x.ndim - n.ndim))
    var = jnp.sum(jnp.square(xf), axis=-1, keepdims=True) / jnp.maximum(n, 1.0)
    y = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    return y.astype(dt)


# ---------------------------------------------------------------------------
# positions
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float):
    """Apply rotary embeddings. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (math.log(theta) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(positions, d: int):
    """Absolute sinusoidal positions (whisper-style). positions: (..., S)."""
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32) * (math.log(10000.0) / max(half - 1, 1)))
    angles = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


# ---------------------------------------------------------------------------
# MLP (dense)
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    pd = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], (d, f), dtype=pd), "wo": dense_init(ks[1], (f, d), dtype=pd)}
    if cfg.activation == "swiglu":
        p["wg"] = dense_init(ks[2], (d, f), dtype=pd)
    return p


def apply_mlp(params, x, cfg: ModelConfig, active_ff=None):
    """Dense MLP. ``active_ff`` (scalar or per-batch (B,)) runtime-gates the
    hidden columns: columns >= active_ff are exactly zero after the up
    projection (so every activation maps 0 -> 0 across them) and are skipped
    by the down projection's contraction."""
    dt = x.dtype
    h = morph_proj(x, params["wi"], active_n=active_ff)
    if cfg.activation == "swiglu":
        g = morph_proj(x, params["wg"], active_n=active_ff)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * h
    elif cfg.activation == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    else:  # gelu
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(dt)
    return morph_proj(h, params["wo"], active_k=active_ff)


# ---------------------------------------------------------------------------
# GQA attention (full / sliding window; train, prefill, decode)
# ---------------------------------------------------------------------------

NEG_INF = -1e9


def init_attention(key, cfg: ModelConfig, cross: bool = False):
    d = cfg.d_model
    pd = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, cfg.q_dim), dtype=pd),
        "wk": dense_init(ks[1], (d, cfg.kv_dim), dtype=pd),
        "wv": dense_init(ks[2], (d, cfg.kv_dim), dtype=pd),
        "wo": dense_init(ks[3], (cfg.q_dim, d), dtype=pd),
    }


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _attn_mask(q_pos, k_pos, causal: bool, window: int):
    """(..., Sq, Sk) additive mask."""
    m = jnp.zeros(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]), jnp.float32)
    dq = q_pos[..., :, None]
    dk = k_pos[..., None, :]
    m = jnp.where(dk < 0, NEG_INF, m)  # unwritten / padded slots carry pos < 0
    if causal:
        m = jnp.where(dk > dq, NEG_INF, m)
    if window > 0:
        m = jnp.where(dk <= dq - window, NEG_INF, m)
    return m


def _gqa_scores(q, k, cfg: ModelConfig):
    """q: (B,Sq,H,hd), k: (B,Sk,KV,hd) -> (B,KV,H/KV,Sq,Sk) f32."""
    groups = cfg.n_heads // max(cfg.n_kv_heads, 1)
    B, Sq, H, hd = q.shape
    qg = q.reshape(B, Sq, cfg.n_kv_heads, groups, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k, preferred_element_type=jnp.float32)
    return s / math.sqrt(hd)


def _gqa_out(w, v, cfg: ModelConfig):
    """w: (B,KV,G,Sq,Sk) f32, v: (B,Sk,KV,hd) -> (B,Sq,H,hd).

    v stays in its storage dtype (bf16): upcasting the whole cache to f32
    would double the decode HBM stream; the MXU accumulates in f32 via
    preferred_element_type regardless.
    """
    B = w.shape[0]
    o = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, o.shape[1], cfg.n_heads, cfg.head_dim)


def attention_full(q, k, v, cfg: ModelConfig, q_pos, k_pos, causal=True,
                   bias=None):
    """Plain einsum attention (used for short sequences).

    ``bias`` is an optional additive (Sq, Sk) f32 term on top of the
    position mask — the token-tree verify path uses it to restrict each
    tree node's attention to its ancestors (position masking alone cannot
    separate siblings at equal depth).
    """
    s = _gqa_scores(q, k, cfg)
    mask = _attn_mask(q_pos, k_pos, causal, cfg.sliding_window)
    s = s + mask[:, None, None] if mask.ndim == 3 else s + mask
    if bias is not None:
        s = s + bias
    w = jax.nn.softmax(s, axis=-1)
    return _gqa_out(w, v, cfg).astype(q.dtype)


def attention_chunked(q, k, v, cfg: ModelConfig, q_pos, k_pos, causal=True):
    """Blockwise (flash-style) attention in pure JAX.

    Scans over KV chunks carrying running (max, sum, acc) so peak memory is
    O(Sq * chunk) instead of O(Sq * Sk). This is the default for long
    sequences in dry-run lowering (honest FLOPs, bounded memory); the Pallas
    kernel in ``repro.kernels.flash_attention`` is the TPU-native fast path.
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    chunk = min(cfg.attn_chunk, Sk)
    n_chunks = (Sk + chunk - 1) // chunk
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, pad),), constant_values=-10**9)
    kc = k.reshape(B, n_chunks, chunk, cfg.n_kv_heads, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, cfg.n_kv_heads, hd).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(n_chunks, chunk)
    groups = H // max(cfg.n_kv_heads, 1)
    qg = q.reshape(B, Sq, cfg.n_kv_heads, groups, hd)

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        k_i, v_i, p_i = xs
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k_i, preferred_element_type=jnp.float32)
        s = s / math.sqrt(hd)
        mask = _attn_mask(q_pos, p_i, causal, cfg.sliding_window)
        s = s + mask  # (B?,Sq,chunk) broadcast over (b,k,g,..)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(v_i.dtype), v_i,
                        preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, cfg.n_kv_heads, groups, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, cfg.n_kv_heads, groups, Sq), jnp.float32)
    a0 = jnp.zeros((B, cfg.n_kv_heads, groups, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def mha(params, x, cfg: ModelConfig, positions, *, kv_x=None, kv_positions=None,
        causal=True):
    """Full-sequence attention (train/prefill). Returns (out, (k, v))."""
    dt = x.dtype
    q = _split_heads(matmul(x, params["wq"], dt), cfg.n_heads, cfg.head_dim)
    kv_in = x if kv_x is None else kv_x
    k = _split_heads(matmul(kv_in, params["wk"], dt), cfg.n_kv_heads, cfg.head_dim)
    v = _split_heads(matmul(kv_in, params["wv"], dt), cfg.n_kv_heads, cfg.head_dim)
    kpos = positions if kv_positions is None else kv_positions
    if cfg.use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kpos, cfg.rope_theta)
    # serving prefill under a mesh: same by-head pinning as the decode path
    # (no-ops outside an activation_sharding context, e.g. in training)
    q = constrain(q, "decode_q")
    k = constrain(k, "decode_kv")
    v = constrain(v, "decode_kv")
    Sk = k.shape[1]
    impl = cfg.attn_impl
    if impl == "auto":
        impl = "chunked" if Sk > 2048 else "einsum"
    fn = attention_chunked if impl == "chunked" else attention_full
    out = fn(q, k, v, cfg, positions, kpos, causal=causal)
    out = matmul(out.reshape(out.shape[0], out.shape[1], cfg.q_dim), params["wo"], dt)
    return out, (k, v)


# --- decode path with KV cache ---------------------------------------------


def quantize_kv(x):
    """int8 per-(batch,pos,head) absmax quantization."""
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True).astype(jnp.float32) / 127.0
    q = jnp.round(x.astype(jnp.float32) / jnp.maximum(scale, 1e-8)).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def init_kv_cache(cfg: ModelConfig, batch: int, seq: int, dtype):
    """KV cache for one attention layer. SWA uses a rolling window buffer."""
    window = cfg.sliding_window
    s = min(seq, window) if window else seq
    shape = (batch, s, cfg.n_kv_heads, cfg.head_dim)
    if cfg.kv_quant:
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(shape[:-1] + (1,), jnp.bfloat16),
            "v_scale": jnp.zeros(shape[:-1] + (1,), jnp.bfloat16),
        }
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def mha_decode(params, x, cache, pos, cfg: ModelConfig, *, cross=False,
               active=None, pages=None, page_size=0, fused=False):
    """One-token decode. x: (B,1,d); cache dict; pos: scalar int32 or (B,)
    per-slot positions (continuous batching: each batch slot is an independent
    request at its own sequence offset).

    ``active`` (dict with "q_dim"/"kv_dim", scalars or per-batch (B,))
    runtime-gates the projections: q/k/v columns beyond each slot's active
    width are exactly zero, so inactive heads score uniformly over zero
    values and contribute nothing, and the output projection's contraction
    skips inactive head columns — one executable serves every width.

    ``pages`` switches the cache to the block-paged layout (see
    ``models.paged``): cache K/V leaves are physical page pools
    ``(n_pages, page_size, KV, hd)`` and ``pages`` is the traced
    ``(B, P)`` int32 page table. The new K/V is written to the physical
    (page, offset) the slot's position maps to, then attention runs over the
    gathered per-slot view — garbage columns (table entries past a slot's
    length) sit at kpos = -1e9 and contribute exact zeros, so the paged
    path is bit-identical to the dense one. Requires per-slot positions.

    Returns (out, new_cache). For cross-attention the cache holds precomputed
    encoder K/V and is returned unchanged.

    ``fused=True`` routes the self-attention branch through the
    ``kernels.fused_decode`` superkernel (projection + attention + dequant in
    one launch; ``impl="auto"``: Pallas on TPU, a bit-identical mirrored ref
    elsewhere). Cross-attention ignores the flag (no cache write, tiny S).
    """
    if fused and not cross:
        from repro.kernels import fused_decode_step  # local: keep layers import-light
        return fused_decode_step(params, x, cache, pos, cfg, active=active,
                                 pages=pages, page_size=page_size)
    dt = x.dtype
    B = x.shape[0]
    a_q = active.get("q_dim") if active else None
    a_kv = active.get("kv_dim") if active else None
    pos = jnp.asarray(pos, jnp.int32)
    per_slot = pos.ndim == 1
    qpos = pos[:, None] if per_slot else jnp.full((1,), pos, jnp.int32)
    q = _split_heads(morph_proj(x, params["wq"], active_n=a_q),
                     cfg.n_heads, cfg.head_dim)
    if cfg.use_rope and not cross:
        q = rope(q, qpos, cfg.rope_theta)
    # under a mesh, pin the post-projection layout to by-head sharding (or
    # replication): attention math must never be split through head_dim,
    # which is what the fused projection's column sharding would propagate
    q = constrain(q, "decode_q")

    if cross:
        k, v = cache["k"], cache["v"]
        if cfg.kv_quant and "k_scale" in cache:
            k = dequantize_kv(k, cache["k_scale"], dt)
            v = dequantize_kv(v, cache["v_scale"], dt)
        S = k.shape[1]
        kpos = jnp.arange(S)
        out = attention_full(q, k, v, cfg, qpos, kpos, causal=False)
        # cross K/V is full-width encoder output, so inactive q heads attend
        # to NON-zero values — the active_k contraction gate on wo is what
        # excludes them, not zero propagation.
        out = morph_proj(out.reshape(B, 1, cfg.q_dim), params["wo"], active_k=a_q)
        return out, cache

    k_new = _split_heads(morph_proj(x, params["wk"], active_n=a_kv),
                         cfg.n_kv_heads, cfg.head_dim)
    v_new = _split_heads(morph_proj(x, params["wv"], active_n=a_kv),
                         cfg.n_kv_heads, cfg.head_dim)
    if cfg.use_rope:
        k_new = rope(k_new, qpos, cfg.rope_theta)
    k_new = constrain(k_new, "decode_kv")
    v_new = constrain(v_new, "decode_kv")

    window = cfg.sliding_window
    if pages is not None:
        if not per_slot:
            raise ValueError("paged decode needs per-slot positions (pos (B,))")
        ps = page_size
        S = pages.shape[1] * ps  # positions visible through the table
        slot = jnp.mod(pos, S) if window else jnp.minimum(pos, S - 1)
        page_ix = slot // ps
        off = slot - page_ix * ps
        phys = jnp.take_along_axis(pages, page_ix[:, None], axis=1)[:, 0]

        def write(buf, new):  # buf: (n_pages, page_size, ...)
            return buf.at[phys, off].set(new[:, 0].astype(buf.dtype))

        def view(buf):
            g = jnp.take(buf, pages, axis=0)
            return g.reshape((B, S) + buf.shape[2:])
    else:
        S = cache["k"].shape[1]
        slot = jnp.mod(pos, S) if window else jnp.minimum(pos, S - 1)

        def view(buf):
            return buf

        if per_slot:
            batch_ix = jnp.arange(B)

            def write(buf, new):
                return buf.at[batch_ix, slot].set(new[:, 0].astype(buf.dtype))
        else:
            def write(buf, new):
                return jax.lax.dynamic_update_slice_in_dim(
                    buf, new.astype(buf.dtype), slot, axis=1)

    new_cache = dict(cache)
    if cfg.kv_quant:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        new_cache["k"] = write(cache["k"], kq)
        new_cache["v"] = write(cache["v"], vq)
        new_cache["k_scale"] = write(cache["k_scale"], ks)
        new_cache["v_scale"] = write(cache["v_scale"], vs)
        k = dequantize_kv(view(new_cache["k"]), view(new_cache["k_scale"]), dt)
        v = dequantize_kv(view(new_cache["v"]), view(new_cache["v_scale"]), dt)
    else:
        new_cache["k"] = write(cache["k"], k_new)
        new_cache["v"] = write(cache["v"], v_new)
        k, v = view(new_cache["k"]).astype(dt), view(new_cache["v"]).astype(dt)
    if pages is not None:
        # mesh serving: the gather collapses the pool's page axis into a
        # per-slot seq axis — pin the result back to the by-head layout the
        # attention math below assumes (no-op outside a sharding context)
        k = constrain(k, "decode_kv")
        v = constrain(v, "decode_kv")

    # kpos: absolute position of each cache slot. With per-slot pos the mask
    # broadcasts to (B, S) — stale entries from a slot's previous request sit
    # at idx > pos and are masked out, which is what makes in-place slot
    # re-admission safe without zeroing the KV buffer.
    pos_b = pos[:, None] if per_slot else pos
    idx = jnp.arange(S)[None, :] if per_slot else jnp.arange(S)
    if window:
        # rolling buffer: absolute position of slot i given current pos
        wraps = jnp.where(idx <= jnp.mod(pos_b, S), 0, 1)
        kpos = (pos_b // S - wraps) * S + idx  # absolute positions, may be negative
        kpos = jnp.where(kpos < 0, -10**9, kpos)  # unwritten slots -> masked
    else:
        kpos = jnp.where(idx <= pos_b, idx, -10**9)
    out = attention_full(q, k, v, cfg, qpos, kpos, causal=True)
    out = morph_proj(out.reshape(B, 1, cfg.q_dim), params["wo"], active_k=a_q)
    return out, new_cache


def _cache_kpos(pos, n_slots: int, window: int):
    """Absolute position of every cache slot given ``pos`` committed tokens.

    ``pos`` is the (B,) per-slot committed-token count — the cache holds
    entries for absolute positions < pos only. Returns (B, n_slots) int32
    with unwritten / stale / rolled-over slots at -1e9 (masked).
    """
    idx = jnp.arange(n_slots)[None, :]
    if window:
        last = pos[:, None] - 1  # newest committed absolute position (-1: none)
        wraps = jnp.where(idx <= jnp.mod(last, n_slots), 0, 1)
        kpos = (jnp.floor_divide(last, n_slots) - wraps) * n_slots + idx
        return jnp.where(kpos < 0, -10**9, kpos)
    return jnp.where(idx < pos[:, None], idx, -10**9)


def mha_verify(params, x, cache, pos, cfg: ModelConfig, *, active=None,
               node_depth=None, tree_bias=None, pages=None, page_size=0,
               fused=False):
    """Speculative verify attention: score S positions in one pass.

    x: (B, S, d) — embeddings of the last committed token followed by S-1
    draft tokens, occupying absolute positions ``pos .. pos+S-1`` (``pos`` is
    the (B,) per-slot committed-token count). The cache is READ but never
    written: new K/V for the S positions are returned as candidates for
    ``models.model.commit_verify`` to scatter once the acceptance count is
    known. Attention runs over [cache entries, new K/V] with absolute-position
    masking, so each query sees exactly the keys a sequential ``mha_decode``
    stream would have seen — including the rolling sliding-window buffer,
    where attending BEFORE any write avoids clobbering entries that later
    (rejected) positions would have rolled over.

    Token-tree verify: ``node_depth`` (S,) static ints map each position to
    its tree depth (absolute position ``pos + depth``) and ``tree_bias``
    (S, S) is the static ancestor mask (0 ancestor-or-self / -inf) applied
    over the new-KV block — position masking alone cannot separate sibling
    branches sitting at the same depth. Default (both None) is the linear
    window ``pos .. pos+S-1``.

    With ``pages`` (traced (B, P) int32 table; see ``models.paged``) the
    cache operands are page pools and the committed K/V is read through the
    gathered per-slot view — same masking argument as ``mha_decode``, same
    bit-identity to the dense path.

    Returns (out (B, S, d), {"k": k_new, "v": v_new} with (B, S, KV, hd)).

    ``fused=True`` routes through the ``kernels.fused_decode`` verify
    superkernel; tree topologies bake their ancestor mask into the kernel
    instead of materializing this function's dense additive ``bias``.
    """
    if fused:
        from repro.kernels import fused_verify  # local: keep layers import-light
        return fused_verify(params, x, cache, pos, cfg, active=active,
                            node_depth=node_depth, tree_bias=tree_bias,
                            pages=pages, page_size=page_size)
    dt = x.dtype
    B, S, _ = x.shape
    a_q = active.get("q_dim") if active else None
    a_kv = active.get("kv_dim") if active else None
    pos = jnp.asarray(pos, jnp.int32)
    offs = (jnp.arange(S, dtype=jnp.int32) if node_depth is None
            else jnp.asarray(node_depth, jnp.int32))
    qpos = pos[:, None] + offs[None, :]  # (B, S)
    # pin BEFORE rope as well as after: at (B, S>1) decode shapes the XLA CPU
    # partitioner mis-lowers rope over projection-propagated column sharding
    # (wrong values, not just slow — same bug class decode_specs documents)
    q = constrain(_split_heads(morph_proj(x, params["wq"], active_n=a_q),
                               cfg.n_heads, cfg.head_dim), "decode_q")
    k_new = constrain(_split_heads(morph_proj(x, params["wk"], active_n=a_kv),
                                   cfg.n_kv_heads, cfg.head_dim), "decode_kv")
    v_new = constrain(_split_heads(morph_proj(x, params["wv"], active_n=a_kv),
                                   cfg.n_kv_heads, cfg.head_dim), "decode_kv")
    if cfg.use_rope:
        q = rope(q, qpos, cfg.rope_theta)
        k_new = rope(k_new, qpos, cfg.rope_theta)
    q = constrain(q, "decode_q")
    k_new = constrain(k_new, "decode_kv")
    v_new = constrain(v_new, "decode_kv")

    if pages is not None:
        Sv = pages.shape[1] * page_size

        def _view(buf):
            g = jnp.take(buf, pages, axis=0)
            return g.reshape((B, Sv) + buf.shape[2:])

        kc, vc = _view(cache["k"]), _view(cache["v"])
        if cfg.kv_quant and "k_scale" in cache:
            kc = dequantize_kv(kc, _view(cache["k_scale"]), dt)
            vc = dequantize_kv(vc, _view(cache["v_scale"]), dt)
    else:
        kc, vc = cache["k"], cache["v"]
    if cfg.kv_quant and "k_scale" in cache and pages is None:
        kc = dequantize_kv(kc, cache["k_scale"], dt)
        vc = dequantize_kv(vc, cache["v_scale"], dt)
    if cfg.kv_quant and "k_scale" in cache:
        # attend over the quantize->dequantize round trip of the NEW entries
        # too: that is what sequential mha_decode reads back from the cache,
        # and what commit_verify will store — raw values would break the
        # verify-equals-sequential-decode identity. Candidates stay raw
        # (commit re-quantizes them to the same stored values).
        kq, ks_ = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        k_att = dequantize_kv(kq, ks_, dt)
        v_att = dequantize_kv(vq, vs, dt)
    else:
        k_att, v_att = k_new, v_new
    # under a mesh the serving cache keeps KV seq sharded on the model axis;
    # concatenating it with the replicated new K/V along that axis is one of
    # the layouts the XLA CPU partitioner gets WRONG (verified: bad logits at
    # every position) — pin the cache operand to the verify layout first
    kc = constrain(kc.astype(dt), "decode_kv")
    vc = constrain(vc.astype(dt), "decode_kv")
    kpos_c = _cache_kpos(pos, kc.shape[1], cfg.sliding_window)
    k_ext = jnp.concatenate([kc, k_att], axis=1)
    v_ext = jnp.concatenate([vc, v_att], axis=1)
    kpos = jnp.concatenate([kpos_c, qpos], axis=1)
    bias = None
    if tree_bias is not None:
        # cache columns stay position-masked only; new-KV columns get the
        # ancestor mask (sibling/cousin nodes are invisible to each other)
        bias = jnp.concatenate(
            [jnp.zeros((S, kc.shape[1]), jnp.float32),
             jnp.asarray(tree_bias, jnp.float32)], axis=1)
    out = attention_full(q, k_ext, v_ext, cfg, qpos, kpos, causal=True,
                         bias=bias)
    out = morph_proj(out.reshape(B, S, cfg.q_dim), params["wo"], active_k=a_q)
    return out, {"k": k_new, "v": v_new}


def mha_tree_level(params, x, cache, pos, cfg: ModelConfig, carry_kv, *,
                   level, carry_depths, bias, active=None, pages=None,
                   page_size=0):
    """One tree-draft LEVEL of attention: frontier nodes vs cache + carry.

    The KV-carrying tree draft processes each node exactly once: level
    ``level``'s frontier embeddings ``x`` (B, nf, d) attend over the
    committed cache plus the K/V CARRIED from earlier levels instead of
    re-scoring the whole tree prefix per pass. ``carry_kv`` holds
    ``{"k", "v"}`` (B, Nc, KV, hd) round-tripped K/V of already-processed
    nodes in BFS order (rows past the readable prefix are unread zeros);
    ``carry_depths`` is the static (f1,) depth of each readable carry row
    and ``bias`` the static (nf, f1) ancestor-mask rows for the frontier —
    columns [f1-nf, f1) are the frontier's own in-flight K/V.

    Bit-identical to the frontier rows of ``mha_verify`` over the full
    prefix: carried rows equal the values that pass would recompute, and
    the extended key axis keeps the same BFS column order, so the softmax
    reduction is unchanged. Returns (out (B, nf, d), rows {"k", "v"}
    (B, nf, KV, hd)) with rows ROUND-TRIPPED through kv quantization (what
    a cache read-back would return) — ready to write into the carry.
    """
    dt = x.dtype
    B, nf, _ = x.shape
    a_q = active.get("q_dim") if active else None
    a_kv = active.get("kv_dim") if active else None
    pos = jnp.asarray(pos, jnp.int32)
    offs = jnp.full((nf,), level, jnp.int32)  # one level = one depth
    qpos = pos[:, None] + offs[None, :]  # (B, nf)
    q = constrain(_split_heads(morph_proj(x, params["wq"], active_n=a_q),
                               cfg.n_heads, cfg.head_dim), "decode_q")
    k_new = constrain(_split_heads(morph_proj(x, params["wk"], active_n=a_kv),
                                   cfg.n_kv_heads, cfg.head_dim), "decode_kv")
    v_new = constrain(_split_heads(morph_proj(x, params["wv"], active_n=a_kv),
                                   cfg.n_kv_heads, cfg.head_dim), "decode_kv")
    if cfg.use_rope:
        q = rope(q, qpos, cfg.rope_theta)
        k_new = rope(k_new, qpos, cfg.rope_theta)
    q = constrain(q, "decode_q")
    k_new = constrain(k_new, "decode_kv")
    v_new = constrain(v_new, "decode_kv")

    if pages is not None:
        Sv = pages.shape[1] * page_size

        def _view(buf):
            g = jnp.take(buf, pages, axis=0)
            return g.reshape((B, Sv) + buf.shape[2:])

        kc, vc = _view(cache["k"]), _view(cache["v"])
        if cfg.kv_quant and "k_scale" in cache:
            kc = dequantize_kv(kc, _view(cache["k_scale"]), dt)
            vc = dequantize_kv(vc, _view(cache["v_scale"]), dt)
    else:
        kc, vc = cache["k"], cache["v"]
        if cfg.kv_quant and "k_scale" in cache:
            kc = dequantize_kv(kc, cache["k_scale"], dt)
            vc = dequantize_kv(vc, cache["v_scale"], dt)
    if cfg.kv_quant and "k_scale" in cache:
        # same round trip the verify path attends over (see mha_verify)
        kq, ks_ = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        k_att = dequantize_kv(kq, ks_, dt)
        v_att = dequantize_kv(vq, vs, dt)
    else:
        k_att, v_att = k_new, v_new
    kc = constrain(kc.astype(dt), "decode_kv")
    vc = constrain(vc.astype(dt), "decode_kv")
    kpos_c = _cache_kpos(pos, kc.shape[1], cfg.sliding_window)
    f1 = bias.shape[1]  # readable carry prefix (ancestors + frontier)
    k_car = jnp.concatenate([carry_kv["k"][:, : f1 - nf].astype(dt), k_att], 1)
    v_car = jnp.concatenate([carry_kv["v"][:, : f1 - nf].astype(dt), v_att], 1)
    kpos_car = pos[:, None] + jnp.asarray(carry_depths, jnp.int32)[None, :]
    k_ext = jnp.concatenate([kc, k_car], axis=1)
    v_ext = jnp.concatenate([vc, v_car], axis=1)
    kpos = jnp.concatenate([kpos_c, kpos_car], axis=1)
    bias_full = jnp.concatenate(
        [jnp.zeros((nf, kc.shape[1]), jnp.float32),
         jnp.asarray(bias, jnp.float32)], axis=1)
    out = attention_full(q, k_ext, v_ext, cfg, qpos, kpos, causal=True,
                         bias=bias_full)
    out = morph_proj(out.reshape(B, nf, cfg.q_dim), params["wo"], active_k=a_q)
    return out, {"k": k_att, "v": v_att}
