"""Block-paged attention-KV layout: page pool + page-table indexing.

Dense serving caches give every batch slot a private ``capacity``-long K/V
buffer — max concurrency equals ``n_slots`` and every request pays worst-case
sequence length. The paged layout replaces the per-slot buffers with ONE
physical page pool per layer group:

    dense:  k  (n_groups, n_slots, capacity, KV, hd)
    paged:  k  (n_groups, n_pages, page_size, KV, hd)

plus a per-slot *page table* — a small ``(n_slots, n_pages_mapped)`` int32
array mapping each slot's logical page ``p`` (positions ``p*page_size ..``)
to a physical page. The table is host-managed (``runtime.paged_cache``) and
rides each decode/verify launch as a traced operand, so remapping pages never
recompiles, and two slots whose prompts share a full-page prefix can point
their first table entries at the SAME physical blocks.

Only attention K/V is paged. SSM conv tails / state are O(1) per slot and
recurrent (no sequence axis to page), so they stay per-slot dense — the cache
is heterogeneous by design, and every consumer (reset/adopt/sharding/commit)
dispatches on leaf names (``_PAGED_KEYS``) rather than assuming one layout.

Exactness: a slot's gathered view ``pool[table[i]]`` reshaped to
``(Sv, KV, hd)`` reproduces the dense buffer's first ``Sv`` columns wherever
the dense buffer was written; remaining columns hold garbage from other
requests, but every such column sits at ``kpos`` masked to -1e9 and
``exp(-1e9 + s)`` underflows to exactly 0.0 in f32 — adding exact zeros
leaves every softmax/output reduction bit-identical to the dense path. The
equivalence tests in ``tests/test_serving_paged.py`` assert token identity,
not closeness.

Compile keys: the traced table's WIDTH (max pages visible to a launch) is a
shape, hence a compile key. ``PagedLayout.buckets`` quantizes widths to a
power-of-two ladder so the zero-re-trace discipline survives variable-length
slots — all slots whose page counts fall in one bucket share one executable.
Sliding-window groups use a single fixed bucket (the rolling buffer never
grows past ``window // page_size`` pages).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ssm as SSM

# Cache leaf names that live in the paged pool (everything else — SSM conv
# tails/state, encoder cross-K/V — stays per-slot dense).
_PAGED_KEYS = ("k", "v", "k_scale", "v_scale")


def is_paged_key(name: str) -> bool:
    return name in _PAGED_KEYS


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Static description of a block-paged KV cache.

    ``page_size``: tokens per physical page. ``n_pages``: total physical
    pages in the pool, or None to size for the worst case (every slot at
    full length, plus one scratch page per slot — see ``pool_pages``).
    """

    page_size: int
    n_pages: Optional[int] = None

    def validate(self, cfg: ModelConfig, capacity: int) -> None:
        ps = self.page_size
        if ps <= 0:
            raise ValueError(f"kv page size must be positive, got {ps}")
        if capacity % ps:
            raise ValueError(
                f"kv page size {ps} must divide the cache capacity {capacity}")
        if cfg.sliding_window and cfg.sliding_window % ps:
            raise ValueError(
                f"kv page size {ps} must divide the sliding window "
                f"{cfg.sliding_window} (the rolling buffer wraps at page "
                f"boundaries)")
        if self.n_pages is not None and self.n_pages <= 0:
            raise ValueError(f"kv page pool must be positive, got {self.n_pages}")

    def seq_capacity(self, cfg: ModelConfig, capacity: int) -> int:
        """Max cache positions per slot (the dense buffer's seq length)."""
        w = cfg.sliding_window
        return min(capacity, w) if w else capacity

    def cap_pages(self, cfg: ModelConfig, capacity: int) -> int:
        """Logical pages a slot needs at full length (= max table width)."""
        return self.seq_capacity(cfg, capacity) // self.page_size

    def pool_pages(self, cfg: ModelConfig, batch: int, capacity: int) -> int:
        """Physical pool size: explicit ``n_pages`` or the safe default.

        The default guarantees allocation can never fail: every slot at full
        length plus (full attention only) one permanently-owned scratch page
        per slot that free slots' table rows point at, so whole-batch
        launches write their garbage somewhere harmless.
        """
        if self.n_pages is not None:
            return self.n_pages
        scratch = 0 if cfg.sliding_window else 1
        return batch * (self.cap_pages(cfg, capacity) + scratch)

    def buckets(self, cfg: ModelConfig, capacity: int) -> Tuple[int, ...]:
        """Page-table widths that become compile keys (ascending).

        Full attention: powers of two up to the full-length page count, plus
        the full count. Sliding window: one fixed bucket — the rolling
        buffer is always ``window // page_size`` pages wide.
        """
        cp = self.cap_pages(cfg, capacity)
        if cfg.sliding_window:
            return (cp,)
        out = []
        b = 1
        while b < cp:
            out.append(b)
            b *= 2
        out.append(cp)
        return tuple(out)

    def bucket_for(self, cfg: ModelConfig, capacity: int, needed: int) -> int:
        """Smallest bucket covering ``needed`` pages."""
        for b in self.buckets(cfg, capacity):
            if b >= needed:
                return b
        return self.cap_pages(cfg, capacity)


def init_paged_cache(cfg: ModelConfig, batch: int, capacity: int,
                     layout: PagedLayout):
    """Zeroed paged serving cache (always per-slot / continuous-batching).

    Same pytree structure as ``init_decode_cache(per_slot=True)`` except the
    attention leaves are page pools ``(n_groups, n_pages, page_size, KV, hd)``
    shared by all slots. ``pos`` stays the per-slot committed-token counter —
    position masking over the gathered view works exactly as it does over
    the dense buffers.
    """
    if cfg.is_encdec or cfg.frontend:
        raise NotImplementedError("paged cache supports token-only decoders")
    layout.validate(cfg, capacity)
    dt = jnp.dtype(cfg.dtype)
    ps = layout.page_size
    n_pages = layout.pool_pages(cfg, batch, capacity)

    def one_layer(p: int):
        kind = cfg.layer_kind(p)
        if kind != "attn":
            return SSM.init_ssm_cache(cfg, batch, dtype=dt)
        shape = (n_pages, ps, cfg.n_kv_heads, cfg.head_dim)
        if cfg.kv_quant:
            return {
                "k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:-1] + (1,), jnp.bfloat16),
                "v_scale": jnp.zeros(shape[:-1] + (1,), jnp.bfloat16),
            }
        return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}

    stack = {f"pos{p}": jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (cfg.n_groups,) + a.shape), one_layer(p))
        for p in range(cfg.period)}
    pos = jnp.zeros((batch,), jnp.int32)
    return {"pos": pos, "stack": stack}


def paged_view(buf, pages, page_size: int):
    """Gather a slot-major view from a page pool.

    buf: (n_pages, page_size, ...); pages: (B, P) int32 page table. Returns
    (B, P*page_size, ...) — each slot's logical sequence, garbage wherever
    the table points at pages the slot doesn't own (masked by kpos).
    """
    g = jnp.take(buf, pages, axis=0)  # (B, P, page_size, ...)
    B, P = pages.shape
    return g.reshape((B, P * page_size) + buf.shape[2:])


def adopt_paged_slot(cache, pre, slot, pages, write_mask, page_size: int):
    """Adopt a prefilled slot's state into a paged serving cache.

    ``pre`` is a dense ``prefill(per_slot=True, slot=...)`` cache whose
    attention buffers cover at least ``len(pages) * page_size`` positions.
    Attention lanes are reshaped into pages and scattered to the physical
    pages in ``pages`` (traced (ncp,) int32); ``write_mask`` (ncp,) bool
    skips pages already resident via the shared-prefix radix — the prefill
    recomputed identical K/V for those positions, and NOT writing them is
    what lets one physical block back many slots. SSM state and the position
    counter copy densely, exactly like ``adopt_cache_slot``.
    """
    ps = page_size
    ncp = pages.shape[0]
    m = jnp.asarray(write_mask)
    new_stack = {}
    for pname, layer in cache["stack"].items():
        pl = pre["stack"][pname]
        nl = {}
        for kname, full in layer.items():
            new = pl[kname]
            if kname in _PAGED_KEYS:
                lane = new[:, slot]  # (G, S_pre, ...)
                seg = lane[:, :ncp * ps]
                seg = seg.reshape((seg.shape[0], ncp, ps) + seg.shape[2:])
                old = full[:, pages]  # (G, ncp, page_size, ...)
                wm = m.reshape((1, ncp) + (1,) * (seg.ndim - 2))
                nl[kname] = full.at[:, pages].set(
                    jnp.where(wm, seg.astype(full.dtype), old))
            else:
                nl[kname] = full.at[:, slot].set(new[:, slot].astype(full.dtype))
        new_stack[pname] = nl
    pos = cache["pos"].at[slot].set(pre["pos"][slot])
    return {"pos": pos, "stack": new_stack}


def copy_page(cache, src, dst):
    """Copy physical page ``src`` onto ``dst`` in every pooled leaf.

    The copy-on-write primitive: before a slot writes into a page whose
    refcount exceeds one, the host allocates a private page and issues this
    (one jitted call per cache structure — ``src``/``dst`` are traced
    scalars, so divergence points never recompile).
    """
    stack = {pname: {k: (a.at[:, dst].set(a[:, src]) if k in _PAGED_KEYS else a)
                     for k, a in layer.items()}
             for pname, layer in cache["stack"].items()}
    return {"pos": cache["pos"], "stack": stack}
