"""XLA_FLAGS helpers that must run before jax initializes its backend.

``--xla_force_host_platform_device_count`` is only read when the CPU backend
initializes, so mesh-capable CLI entry points (``launch.serve``,
``benchmarks.serve_continuous``, ``benchmarks.width_morph``) call these from
an import preamble. This module is deliberately free of jax imports (and
``repro/__init__`` is empty), so the preamble cannot trigger backend
initialization itself.
"""
from __future__ import annotations

import os
from typing import Optional, Sequence


def force_host_device_count(n: int) -> None:
    """Ensure XLA's CPU host platform exposes >= ``n`` devices.

    No-op when any ``xla_force_host_platform_device_count`` is already set
    (an operator's explicit choice wins) or when ``n`` <= 1. Real
    accelerator backends ignore the flag entirely.
    """
    if n <= 1:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        return
    os.environ["XLA_FLAGS"] = \
        f"{flags} --xla_force_host_platform_device_count={n}".strip()


def mesh_arg(argv: Sequence[str]) -> Optional[str]:
    """The value of ``--mesh VALUE`` / ``--mesh=VALUE`` in ``argv``, if any.

    Returns None for an absent flag AND for a dangling ``--mesh`` with no
    value — the caller's argparse produces the proper error message for the
    latter; this sniff must never crash before argparse runs.
    """
    for i, a in enumerate(argv):
        if a == "--mesh":
            return argv[i + 1] if i + 1 < len(argv) else None
        if a.startswith("--mesh="):
            return a.split("=", 1)[1]
    return None
