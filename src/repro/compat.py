"""jax version-compatibility shims.

The codebase targets the jax >= 0.7 mesh/shard_map surface (``jax.set_mesh``,
top-level ``jax.shard_map`` with ``check_vma``, ``jax.sharding.AxisType``);
the container image ships jax 0.4.x, where those live under older names:

  * ``jax.set_mesh``            -> ``Mesh`` is itself a context manager
  * ``jax.shard_map(check_vma)``-> ``jax.experimental.shard_map`` (``check_rep``)
  * ``AxisType.Auto``           -> absent; Auto is the only behaviour

Everything (src, subprocess test scripts, benchmarks) goes through this
module so the version split lives in exactly one place.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes, devices=None):
    """``jax.make_mesh`` pinning Auto axis types where the concept exists.

    We rely on GSPMD propagation; jax 0.9 flips the default axis type, so pin
    Auto explicitly whenever the installed jax knows about axis types.
    ``devices`` restricts the mesh to a subset (e.g. a dp*tp serving slice of
    a larger host platform); default is all of ``jax.devices()``.
    """
    kw = {} if devices is None else {"devices": devices}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes, **kw)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes), **kw)


def set_mesh(mesh):
    """``jax.set_mesh(mesh)`` context: falls back to the Mesh object itself,
    which is a context manager on jax <= 0.5."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return mesh


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict.

    jax >= 0.6 returns a dict; 0.4.x returns a one-element list of dicts
    (one per computation). Absent/empty analyses become {}.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Top-level ``jax.shard_map``; on old jax, ``check_vma`` maps to the
    experimental entry point's ``check_rep``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
